//! Serving metrics (DESIGN.md S11, S20): throughput counters + latency
//! histogram, shared by the server threads behind a mutex (coarse-grained
//! is fine — the hot path is the macro computation, not metric updates).
//!
//! Readers consume one [`MetricsSnapshot`] — a consistent view taken
//! under a single lock acquisition — instead of locking around ad-hoc
//! getter reads. The fabric backend (DESIGN.md S15) additionally feeds
//! NoC hop/packet counters and the tile-utilization gauge.
//!
//! S20 additions: the snapshot is machine-readable
//! ([`MetricsSnapshot::to_json`]) and the text [`Metrics::summary`] is
//! *rebuilt from that JSON* ([`MetricsSnapshot::summary_from_json`]), so
//! the two can never disagree; [`Metrics::absorb_trace`] folds a drained
//! trace into per-stage span-duration gauges; and
//! [`Metrics::snapshot_since`] gives a windowed delta view whose rates
//! are computed over the window, not since construction (the long-idle
//! server fix).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::supervisor::ShedReason;
use crate::obs::{TraceKind, TraceReport};
use crate::util::json::{self, Json};
use crate::util::stats::{HistStats, Histogram};

/// Aggregated serving metrics.
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

struct Inner {
    requests: u64,
    batches: u64,
    macs: u64,
    latency_us: Histogram,
    batch_sizes: Histogram,
    // --- event-driven input occupancy (S17) ---
    active_rows: u64,
    row_slots: u64,
    // --- modeled compute energy (S18: per-timestep stream serving) ---
    energy_fj: f64,
    // --- fabric backend only (S15) ---
    noc_packets: u64,
    noc_hops: u64,
    tiles_used: u64,
    tiles_total: u64,
    // --- reliability runtime (S19) ---
    flips_injected: u64,
    flips_detected: u64,
    flips_repaired: u64,
    scrubs: u64,
    scrub_energy_fj: f64,
    scrub_busy_ns: f64,
    sim_time_ns: f64,
    // --- endurance runtime (S22) ---
    recalibrations: u64,
    /// Largest relative λ shift of the most recent recalibration
    /// (gauge: the adaptive controller's evidence signal).
    recal_lambda_shift: f64,
    /// Per-worker die write-pulse ledger (gauge, indexed by worker).
    wear_pulses: Vec<u64>,
    /// Per-worker wear fraction of rated cycles (gauge, 0..=1).
    wear_fraction: Vec<f64>,
    // --- observability (S20) ---
    /// Per-span-kind duration histograms (µs), fed by `absorb_trace`.
    span_durs: BTreeMap<&'static str, Histogram>,
    /// Pool channel depth high-water mark (gauge).
    pool_queue_hw: u64,
    trace_events: u64,
    trace_dropped: u64,
    // --- supervision control plane (S21) ---
    worker_panics: u64,
    restarts: u64,
    sheds_queue: u64,
    sheds_deadline: u64,
    sheds_drain: u64,
    sheds_restart: u64,
    scrubs_skipped: u64,
    /// Workers degraded after exhausting restart budgets (gauge, set).
    degraded_workers: u64,
    /// Detached pool tasks that panicked (gauge; callers fold in the
    /// cumulative `util::pool::panics()` via max).
    pool_panics: u64,
    // --- network front end (S23) ---
    wire_requests: u64,
    wire_sheds: u64,
    wire_disconnects: u64,
    wire_malformed: u64,
    /// Last stored windowed report (periodic worker reports, S21).
    window: Option<MetricsSnapshot>,
}

/// p50/p95 duration digest of one span kind (from absorbed traces).
#[derive(Debug, Clone, Default)]
pub struct SpanStat {
    /// `obs::TraceKind::name()` of the instrumented site.
    pub name: String,
    pub count: u64,
    pub p50_us: f64,
    pub p95_us: f64,
}

/// One consistent view of every serving counter.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    /// MAC operations executed (2 OPs each).
    pub macs: u64,
    /// Observation window: time since construction for
    /// [`Metrics::snapshot`], the delta window for
    /// [`MetricsSnapshot::delta_since`].
    pub uptime_s: f64,
    /// Requests per second over the window.
    pub rps: f64,
    /// MACs per second over the window.
    pub macs_per_s: f64,
    pub latency_mean_us: f64,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    pub mean_batch: f64,
    /// Full latency distribution digest (cumulative).
    pub latency: HistStats,
    /// Full batch-size distribution digest (cumulative).
    pub batch: HistStats,
    /// Input rows that carried a spike pair, across all served requests
    /// (DESIGN.md S17: the event-driven occupancy of the traffic).
    pub active_rows: u64,
    /// Input row slots offered (`Σ batch × in_dim`; for the stream
    /// backend, macro row slots across all stages).
    pub row_slots: u64,
    /// Modeled compute energy of all served work (fJ; 0 unless the
    /// backend reports it — the stream server does, per timestep).
    pub energy_fj: f64,
    /// Spike packets routed on the fabric NoC (0 for non-fabric backends).
    pub noc_packets: u64,
    /// Total hops those packets travelled.
    pub noc_hops: u64,
    /// Fabric tiles carrying a weight shard (gauge; 0 off-fabric).
    pub tiles_used: u64,
    /// Fabric mesh size (gauge; 0 off-fabric).
    pub tiles_total: u64,
    /// Cells changed by injected retention drift (S19; 0 without a
    /// fault plan).
    pub flips_injected: u64,
    /// Cells found disagreeing with golden during scrub passes.
    pub flips_detected: u64,
    /// Cells restored to golden by scrub rewrites.
    pub flips_repaired: u64,
    /// Scrub passes completed.
    pub scrubs: u64,
    /// SOT write energy spent scrubbing (fJ; also folded into
    /// `energy_fj` so the serving ledger sees it).
    pub scrub_energy_fj: f64,
    /// Simulated array time occupied by scrubbing (ns).
    pub scrub_busy_ns: f64,
    /// Simulated uptime advanced by drift injection (ns).
    pub sim_time_ns: f64,
    /// Online λ recalibrations completed (S22 endurance runtime).
    pub recalibrations: u64,
    /// Largest relative λ shift of the most recent recalibration
    /// (gauge; the adaptive scrub-vs-recalibrate evidence signal).
    pub recal_lambda_shift: f64,
    /// Per-worker die write-pulse ledger (gauge, indexed by worker;
    /// survives worker restarts — same physical die).
    pub wear_pulses: Vec<u64>,
    /// Per-worker wear fraction of rated cycles (gauge, 0..=1).
    pub wear_fraction: Vec<f64>,
    /// Per-stage span duration digests from absorbed traces (S20),
    /// sorted by kind name; empty when no trace was absorbed.
    pub spans: Vec<SpanStat>,
    /// Worker-pool channel depth high-water mark (gauge, S20).
    pub pool_queue_depth_hw: u64,
    /// Trace events absorbed via [`Metrics::absorb_trace`].
    pub trace_events: u64,
    /// Trace events dropped by full rings (drop-oldest policy).
    pub trace_dropped: u64,
    /// Worker panics caught mid-frame (S21; each is either retried on a
    /// restarted worker or accounted as a shed — never silently lost).
    pub worker_panics: u64,
    /// Worker replicas rebuilt after a caught panic.
    pub restarts: u64,
    /// Frames refused at admission (queue at capacity / draining).
    pub sheds_queue: u64,
    /// Frames dropped at dequeue with an expired deadline.
    pub sheds_deadline: u64,
    /// Frames dropped because the drain deadline passed first.
    pub sheds_drain: u64,
    /// Frames dropped by degraded (budget-exhausted) workers.
    pub sheds_restart: u64,
    /// Scrub ticks skipped while ingress queues were deep (S21
    /// idle-stealing scrub scheduling).
    pub scrubs_skipped: u64,
    /// Workers currently degraded (gauge).
    pub degraded_workers: u64,
    /// Detached pool tasks that panicked since process start (gauge).
    pub pool_panics: u64,
    /// Requests decoded off the wire by the network front end (S23;
    /// counts every well-formed frame, whatever the backend then said).
    pub wire_requests: u64,
    /// Shed responses written back over the wire (admission refusals
    /// and dequeue drops, as seen by remote clients).
    pub wire_sheds: u64,
    /// Connections that ended without a `Drain`/orderly close — peer
    /// hangup, I/O error, or a frame so damaged the stream desynced.
    pub wire_disconnects: u64,
    /// Frames rejected by the codec (bad length prefix, oversized,
    /// invalid UTF-8, JSON parse failure, unknown request shape).
    pub wire_malformed: u64,
}

impl MetricsSnapshot {
    /// Fraction of served input rows that were active (0 before any
    /// traffic) — silent rows cost the macro nothing, so this is the
    /// knob the event-list engine's win scales with.
    pub fn input_density(&self) -> f64 {
        if self.row_slots == 0 {
            0.0
        } else {
            self.active_rows as f64 / self.row_slots as f64
        }
    }

    /// Fraction of fabric tiles carrying a weight shard (0 off-fabric).
    pub fn tile_utilization(&self) -> f64 {
        if self.tiles_total == 0 {
            0.0
        } else {
            self.tiles_used as f64 / self.tiles_total as f64
        }
    }

    /// Mean hops per routed spike packet.
    pub fn hops_per_packet(&self) -> f64 {
        if self.noc_packets == 0 {
            0.0
        } else {
            self.noc_hops as f64 / self.noc_packets as f64
        }
    }

    /// Every frame shed anywhere in the pipeline (admission + dequeue).
    pub fn sheds_total(&self) -> u64 {
        self.sheds_queue
            + self.sheds_deadline
            + self.sheds_drain
            + self.sheds_restart
    }

    /// Fraction of submitted frames shed (served = `requests`; a frame
    /// is exactly one of served / shed, asserted by the chaos soak).
    pub fn shed_rate(&self) -> f64 {
        let offered = self.requests + self.sheds_total();
        if offered == 0 {
            0.0
        } else {
            self.sheds_total() as f64 / offered as f64
        }
    }

    /// Worst per-worker wear fraction (0 before any worker published
    /// its ledger) — the number the wear-budget SLO alarms on.
    pub fn wear_max(&self) -> f64 {
        self.wear_fraction.iter().copied().fold(0.0, f64::max)
    }

    /// Fraction of simulated uptime spent scrubbing, clamped to [0, 1]
    /// (an aggressive wall-clock scrubber can overlap serving, so the
    /// raw ratio may exceed 1; 0 before any drift is injected).
    pub fn scrub_duty_cycle(&self) -> f64 {
        if self.sim_time_ns <= 0.0 {
            0.0
        } else {
            (self.scrub_busy_ns / self.sim_time_ns).min(1.0)
        }
    }

    /// Windowed delta view (DESIGN.md S20, the long-idle-server fix):
    /// monotonic counters are differenced against `prev` and the rates
    /// (`rps`, `macs_per_s`) are computed over the window
    /// `self.uptime_s − prev.uptime_s`, so an hour of idle before the
    /// window can no longer dilute them. Distribution digests
    /// (`latency`, `batch`, `spans`, quantile fields) and gauges
    /// (`tiles_*`, `pool_queue_depth_hw`) remain cumulative — bucket
    /// counts are not invertible per window.
    pub fn delta_since(&self, prev: &MetricsSnapshot) -> MetricsSnapshot {
        let window = (self.uptime_s - prev.uptime_s).max(1e-9);
        let requests = self.requests.saturating_sub(prev.requests);
        let macs = self.macs.saturating_sub(prev.macs);
        MetricsSnapshot {
            requests,
            batches: self.batches.saturating_sub(prev.batches),
            macs,
            uptime_s: window,
            rps: requests as f64 / window,
            macs_per_s: macs as f64 / window,
            active_rows: self.active_rows.saturating_sub(prev.active_rows),
            row_slots: self.row_slots.saturating_sub(prev.row_slots),
            energy_fj: (self.energy_fj - prev.energy_fj).max(0.0),
            noc_packets: self.noc_packets.saturating_sub(prev.noc_packets),
            noc_hops: self.noc_hops.saturating_sub(prev.noc_hops),
            flips_injected: self
                .flips_injected
                .saturating_sub(prev.flips_injected),
            flips_detected: self
                .flips_detected
                .saturating_sub(prev.flips_detected),
            flips_repaired: self
                .flips_repaired
                .saturating_sub(prev.flips_repaired),
            scrubs: self.scrubs.saturating_sub(prev.scrubs),
            scrub_energy_fj: (self.scrub_energy_fj - prev.scrub_energy_fj)
                .max(0.0),
            scrub_busy_ns: (self.scrub_busy_ns - prev.scrub_busy_ns)
                .max(0.0),
            sim_time_ns: (self.sim_time_ns - prev.sim_time_ns).max(0.0),
            recalibrations: self
                .recalibrations
                .saturating_sub(prev.recalibrations),
            trace_events: self.trace_events.saturating_sub(prev.trace_events),
            trace_dropped: self
                .trace_dropped
                .saturating_sub(prev.trace_dropped),
            worker_panics: self
                .worker_panics
                .saturating_sub(prev.worker_panics),
            restarts: self.restarts.saturating_sub(prev.restarts),
            sheds_queue: self.sheds_queue.saturating_sub(prev.sheds_queue),
            sheds_deadline: self
                .sheds_deadline
                .saturating_sub(prev.sheds_deadline),
            sheds_drain: self.sheds_drain.saturating_sub(prev.sheds_drain),
            sheds_restart: self
                .sheds_restart
                .saturating_sub(prev.sheds_restart),
            scrubs_skipped: self
                .scrubs_skipped
                .saturating_sub(prev.scrubs_skipped),
            wire_requests: self
                .wire_requests
                .saturating_sub(prev.wire_requests),
            wire_sheds: self.wire_sheds.saturating_sub(prev.wire_sheds),
            wire_disconnects: self
                .wire_disconnects
                .saturating_sub(prev.wire_disconnects),
            wire_malformed: self
                .wire_malformed
                .saturating_sub(prev.wire_malformed),
            // Cumulative distributions and gauges: latest view.
            degraded_workers: self.degraded_workers,
            pool_panics: self.pool_panics,
            recal_lambda_shift: self.recal_lambda_shift,
            wear_pulses: self.wear_pulses.clone(),
            wear_fraction: self.wear_fraction.clone(),
            latency_mean_us: self.latency_mean_us,
            latency_p50_us: self.latency_p50_us,
            latency_p95_us: self.latency_p95_us,
            latency_p99_us: self.latency_p99_us,
            mean_batch: self.mean_batch,
            latency: self.latency,
            batch: self.batch,
            spans: self.spans.clone(),
            tiles_used: self.tiles_used,
            tiles_total: self.tiles_total,
            pool_queue_depth_hw: self.pool_queue_depth_hw,
        }
    }

    /// The machine-readable form (DESIGN.md S20) — the single source
    /// the text summary is rebuilt from. Derived ratios are included
    /// so consumers never recompute them.
    pub fn to_json(&self) -> Json {
        let span_objs: Vec<(&str, Json)> = self
            .spans
            .iter()
            .map(|s| {
                (
                    s.name.as_str(),
                    json::obj(vec![
                        ("count", Json::Num(s.count as f64)),
                        ("p50_us", Json::Num(s.p50_us)),
                        ("p95_us", Json::Num(s.p95_us)),
                    ]),
                )
            })
            .collect();
        json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("macs", Json::Num(self.macs as f64)),
            ("uptime_s", Json::Num(self.uptime_s)),
            ("rps", Json::Num(self.rps)),
            ("macs_per_s", Json::Num(self.macs_per_s)),
            ("latency_us", self.latency.to_json()),
            ("batch_size", self.batch.to_json()),
            ("mean_batch", Json::Num(self.mean_batch)),
            ("active_rows", Json::Num(self.active_rows as f64)),
            ("row_slots", Json::Num(self.row_slots as f64)),
            ("input_density", Json::Num(self.input_density())),
            ("energy_fj", Json::Num(self.energy_fj)),
            (
                "energy_pj_per_request",
                Json::Num(
                    self.energy_fj / 1e3 / self.requests.max(1) as f64,
                ),
            ),
            (
                "noc",
                json::obj(vec![
                    ("packets", Json::Num(self.noc_packets as f64)),
                    ("hops", Json::Num(self.noc_hops as f64)),
                    ("tiles_used", Json::Num(self.tiles_used as f64)),
                    ("tiles_total", Json::Num(self.tiles_total as f64)),
                    (
                        "tile_utilization",
                        Json::Num(self.tile_utilization()),
                    ),
                    ("hops_per_packet", Json::Num(self.hops_per_packet())),
                ]),
            ),
            (
                "reliability",
                json::obj(vec![
                    (
                        "flips_injected",
                        Json::Num(self.flips_injected as f64),
                    ),
                    (
                        "flips_detected",
                        Json::Num(self.flips_detected as f64),
                    ),
                    (
                        "flips_repaired",
                        Json::Num(self.flips_repaired as f64),
                    ),
                    ("scrubs", Json::Num(self.scrubs as f64)),
                    ("scrub_energy_fj", Json::Num(self.scrub_energy_fj)),
                    ("scrub_busy_ns", Json::Num(self.scrub_busy_ns)),
                    ("sim_time_ns", Json::Num(self.sim_time_ns)),
                    (
                        "scrub_duty_cycle",
                        Json::Num(self.scrub_duty_cycle()),
                    ),
                ]),
            ),
            (
                "endurance",
                json::obj(vec![
                    (
                        "recalibrations",
                        Json::Num(self.recalibrations as f64),
                    ),
                    (
                        "recal_lambda_shift",
                        Json::Num(self.recal_lambda_shift),
                    ),
                    (
                        "wear_pulses",
                        Json::Arr(
                            self.wear_pulses
                                .iter()
                                .map(|&p| Json::Num(p as f64))
                                .collect(),
                        ),
                    ),
                    (
                        "wear_fraction",
                        Json::Arr(
                            self.wear_fraction
                                .iter()
                                .copied()
                                .map(Json::Num)
                                .collect(),
                        ),
                    ),
                    ("wear_max", Json::Num(self.wear_max())),
                ]),
            ),
            (
                "supervision",
                json::obj(vec![
                    (
                        "worker_panics",
                        Json::Num(self.worker_panics as f64),
                    ),
                    ("restarts", Json::Num(self.restarts as f64)),
                    ("sheds_queue", Json::Num(self.sheds_queue as f64)),
                    (
                        "sheds_deadline",
                        Json::Num(self.sheds_deadline as f64),
                    ),
                    ("sheds_drain", Json::Num(self.sheds_drain as f64)),
                    (
                        "sheds_restart",
                        Json::Num(self.sheds_restart as f64),
                    ),
                    ("sheds_total", Json::Num(self.sheds_total() as f64)),
                    ("shed_rate", Json::Num(self.shed_rate())),
                    (
                        "scrubs_skipped",
                        Json::Num(self.scrubs_skipped as f64),
                    ),
                    (
                        "degraded_workers",
                        Json::Num(self.degraded_workers as f64),
                    ),
                    ("pool_panics", Json::Num(self.pool_panics as f64)),
                ]),
            ),
            (
                "net",
                json::obj(vec![
                    (
                        "wire_requests",
                        Json::Num(self.wire_requests as f64),
                    ),
                    ("wire_sheds", Json::Num(self.wire_sheds as f64)),
                    (
                        "wire_disconnects",
                        Json::Num(self.wire_disconnects as f64),
                    ),
                    (
                        "wire_malformed",
                        Json::Num(self.wire_malformed as f64),
                    ),
                ]),
            ),
            (
                "pool_queue_depth_hw",
                Json::Num(self.pool_queue_depth_hw as f64),
            ),
            (
                "trace",
                json::obj(vec![
                    ("events", Json::Num(self.trace_events as f64)),
                    ("dropped", Json::Num(self.trace_dropped as f64)),
                ]),
            ),
            ("spans", json::obj(span_objs)),
        ])
    }

    /// The text summary, computed from the JSON alone — every number
    /// printed is read back out of a [`to_json`](Self::to_json) value,
    /// which is what makes the two forms inseparable.
    pub fn summary_from_json(j: &Json) -> String {
        let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let nest = |o: &str, k: &str| {
            j.get(o)
                .and_then(|x| x.get(k))
                .and_then(Json::as_f64)
                .unwrap_or(0.0)
        };
        let lat = j
            .get("latency_us")
            .map(HistStats::from_json)
            .unwrap_or_default();
        let bat = j
            .get("batch_size")
            .map(HistStats::from_json)
            .unwrap_or_default();
        let mut out = format!(
            "requests={} batches={} macs={} rps={:.1} mac/s={:.3e}\n\
             latency_us: {}\n\
             batch_size: {}",
            f("requests") as u64,
            f("batches") as u64,
            f("macs") as u64,
            f("rps"),
            f("macs_per_s"),
            lat.summary_line(),
            bat.summary_line()
        );
        if f("row_slots") > 0.0 {
            out.push_str(&format!(
                "\nactivity: active_rows={} / {} slots ({:.1} % dense)",
                f("active_rows") as u64,
                f("row_slots") as u64,
                f("input_density") * 100.0
            ));
        }
        if f("energy_fj") > 0.0 {
            out.push_str(&format!(
                "\nenergy: {:.1} pJ modeled ({:.2} pJ/request)",
                f("energy_fj") / 1e3,
                f("energy_pj_per_request")
            ));
        }
        if nest("noc", "tiles_total") > 0.0 || nest("noc", "packets") > 0.0 {
            out.push_str(&format!(
                "\nnoc: packets={} hops={} tiles={}/{} ({:.0} % utilized)",
                nest("noc", "packets") as u64,
                nest("noc", "hops") as u64,
                nest("noc", "tiles_used") as u64,
                nest("noc", "tiles_total") as u64,
                nest("noc", "tile_utilization") * 100.0
            ));
        }
        if nest("reliability", "flips_injected") > 0.0
            || nest("reliability", "scrubs") > 0.0
        {
            out.push_str(&format!(
                "\nreliability: flips injected={} detected={} repaired={} \
                 scrubs={} duty={:.1} % scrub_energy={:.1} pJ",
                nest("reliability", "flips_injected") as u64,
                nest("reliability", "flips_detected") as u64,
                nest("reliability", "flips_repaired") as u64,
                nest("reliability", "scrubs") as u64,
                nest("reliability", "scrub_duty_cycle") * 100.0,
                nest("reliability", "scrub_energy_fj") / 1e3
            ));
        }
        if nest("endurance", "recalibrations") > 0.0
            || nest("endurance", "wear_max") > 0.0
        {
            out.push_str(&format!(
                "\nendurance: recals={} last_shift={:.2} % \
                 wear_max={:.4} %",
                nest("endurance", "recalibrations") as u64,
                nest("endurance", "recal_lambda_shift") * 100.0,
                nest("endurance", "wear_max") * 100.0
            ));
        }
        if nest("supervision", "worker_panics") > 0.0
            || nest("supervision", "restarts") > 0.0
            || nest("supervision", "sheds_total") > 0.0
            || nest("supervision", "scrubs_skipped") > 0.0
            || nest("supervision", "degraded_workers") > 0.0
            || nest("supervision", "pool_panics") > 0.0
        {
            out.push_str(&format!(
                "\nsupervision: panics={} restarts={} sheds \
                 queue={} deadline={} drain={} budget={} \
                 (rate {:.1} %) scrub_skips={} degraded={} pool_panics={}",
                nest("supervision", "worker_panics") as u64,
                nest("supervision", "restarts") as u64,
                nest("supervision", "sheds_queue") as u64,
                nest("supervision", "sheds_deadline") as u64,
                nest("supervision", "sheds_drain") as u64,
                nest("supervision", "sheds_restart") as u64,
                nest("supervision", "shed_rate") * 100.0,
                nest("supervision", "scrubs_skipped") as u64,
                nest("supervision", "degraded_workers") as u64,
                nest("supervision", "pool_panics") as u64
            ));
        }
        if nest("net", "wire_requests") > 0.0
            || nest("net", "wire_sheds") > 0.0
            || nest("net", "wire_disconnects") > 0.0
            || nest("net", "wire_malformed") > 0.0
        {
            out.push_str(&format!(
                "\nnet: wire_requests={} sheds={} disconnects={} \
                 malformed={}",
                nest("net", "wire_requests") as u64,
                nest("net", "wire_sheds") as u64,
                nest("net", "wire_disconnects") as u64,
                nest("net", "wire_malformed") as u64
            ));
        }
        if nest("trace", "events") > 0.0
            || nest("trace", "dropped") > 0.0
            || f("pool_queue_depth_hw") > 0.0
        {
            out.push_str(&format!(
                "\ntrace: events={} dropped={} pool_queue_hw={}",
                nest("trace", "events") as u64,
                nest("trace", "dropped") as u64,
                f("pool_queue_depth_hw") as u64
            ));
        }
        if let Some(spans) = j.get("spans").and_then(Json::as_obj) {
            for (name, v) in spans {
                let sf = |k: &str| {
                    v.get(k).and_then(Json::as_f64).unwrap_or(0.0)
                };
                out.push_str(&format!(
                    "\nspan {name}: n={} p50={:.1} us p95={:.1} us",
                    sf("count") as u64,
                    sf("p50_us"),
                    sf("p95_us")
                ));
            }
        }
        out
    }

    /// Text form of this snapshot (via the JSON, see
    /// [`summary_from_json`](Self::summary_from_json)).
    pub fn summary_text(&self) -> String {
        Self::summary_from_json(&self.to_json())
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            inner: Mutex::new(Inner {
                requests: 0,
                batches: 0,
                macs: 0,
                // Log-spaced serving buckets (S20 satellite): same
                // endpoints the hand-written tables had — 10 µs … 200 ms
                // latency, batch 1 … 64 (exactly the powers of two).
                latency_us: Histogram::log_spaced(10.0, 200_000.0, 12),
                batch_sizes: Histogram::log_spaced(1.0, 64.0, 7),
                active_rows: 0,
                row_slots: 0,
                energy_fj: 0.0,
                noc_packets: 0,
                noc_hops: 0,
                tiles_used: 0,
                tiles_total: 0,
                flips_injected: 0,
                flips_detected: 0,
                flips_repaired: 0,
                scrubs: 0,
                scrub_energy_fj: 0.0,
                scrub_busy_ns: 0.0,
                sim_time_ns: 0.0,
                recalibrations: 0,
                recal_lambda_shift: 0.0,
                wear_pulses: Vec::new(),
                wear_fraction: Vec::new(),
                span_durs: BTreeMap::new(),
                pool_queue_hw: 0,
                trace_events: 0,
                trace_dropped: 0,
                worker_panics: 0,
                restarts: 0,
                sheds_queue: 0,
                sheds_deadline: 0,
                sheds_drain: 0,
                sheds_restart: 0,
                scrubs_skipped: 0,
                degraded_workers: 0,
                pool_panics: 0,
                wire_requests: 0,
                wire_sheds: 0,
                wire_disconnects: 0,
                wire_malformed: 0,
                window: None,
            }),
            started: Instant::now(),
        }
    }

    pub fn record_request(&self, latency_us: f64) {
        let mut g = self.inner.lock().unwrap();
        g.requests += 1;
        g.latency_us.record(latency_us);
    }

    pub fn record_batch(&self, size: usize, macs: u64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.macs += macs;
        g.batch_sizes.record(size as f64);
    }

    /// Account one batch's input occupancy (DESIGN.md S17): `active`
    /// rows carried spikes out of `slots` offered.
    pub fn record_activity(&self, active: u64, slots: u64) {
        let mut g = self.inner.lock().unwrap();
        g.active_rows += active;
        g.row_slots += slots;
    }

    /// Account modeled compute energy for served work (fJ, monotonic).
    /// The stream backend calls this per timestep (DESIGN.md S18).
    pub fn record_energy(&self, fj: f64) {
        let mut g = self.inner.lock().unwrap();
        g.energy_fj += fj;
    }

    /// Convenience: input density of all served traffic so far (one
    /// lock, via snapshot). Returns 0.0 — never NaN, never a panic —
    /// on a fresh server with no traffic (`row_slots == 0`).
    pub fn input_density(&self) -> f64 {
        self.snapshot().input_density()
    }

    /// Account routed fabric traffic (counters, monotonic).
    pub fn record_noc(&self, packets: u64, hops: u64) {
        let mut g = self.inner.lock().unwrap();
        g.noc_packets += packets;
        g.noc_hops += hops;
    }

    /// Set the fabric placement gauge (shard-carrying tiles / mesh size).
    pub fn set_tile_usage(&self, used: u64, total: u64) {
        let mut g = self.inner.lock().unwrap();
        g.tiles_used = used;
        g.tiles_total = total;
    }

    /// Raise the pool queue-depth high-water gauge (S20; callers feed
    /// it `util::pool::queue_high_water()`).
    pub fn record_pool_queue_depth(&self, depth: u64) {
        let mut g = self.inner.lock().unwrap();
        g.pool_queue_hw = g.pool_queue_hw.max(depth);
    }

    /// Fold a drained trace into the gauges (S20): per-kind span
    /// duration histograms (µs) behind the p50/p95 [`SpanStat`]s, the
    /// queue-depth high-water from counter samples, and the
    /// event/drop totals. Purely additive — call once per drain.
    pub fn absorb_trace(&self, report: &TraceReport) {
        let mut g = self.inner.lock().unwrap();
        g.trace_events += report.events.len() as u64;
        g.trace_dropped += report.dropped;
        for e in &report.events {
            if e.kind.is_counter() {
                if e.kind == TraceKind::QueueDepth {
                    g.pool_queue_hw =
                        g.pool_queue_hw.max(e.payload[0] as u64);
                }
                continue;
            }
            g.span_durs
                .entry(e.kind.name())
                .or_insert_with(|| Histogram::log_spaced(0.01, 1e7, 16))
                .record(e.dur_ns as f64 / 1e3);
        }
    }

    /// Account one drift-injection round (S19): `flips` cells changed
    /// while the simulated clock advanced by `dt_ns`.
    pub fn record_fault_injection(&self, flips: u64, dt_ns: f64) {
        let mut g = self.inner.lock().unwrap();
        g.flips_injected += flips;
        g.sim_time_ns += dt_ns;
    }

    /// Account one scrub pass (S19): mismatches found, cells restored,
    /// write energy spent, and simulated array time occupied. The
    /// energy also lands in the serving ledger (`energy_fj`), so scrub
    /// cost is visible wherever compute energy is.
    pub fn record_scrub(
        &self,
        detected: u64,
        repaired: u64,
        energy_fj: f64,
        busy_ns: f64,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.scrubs += 1;
        g.flips_detected += detected;
        g.flips_repaired += repaired;
        g.scrub_energy_fj += energy_fj;
        g.scrub_busy_ns += busy_ns;
        g.energy_fj += energy_fj;
    }

    /// Account one online λ recalibration (S22 endurance runtime):
    /// `shift` is the largest relative λ change it produced, kept as a
    /// gauge — the adaptive controller's most recent evidence.
    pub fn record_recalibration(&self, shift: f64) {
        let mut g = self.inner.lock().unwrap();
        g.recalibrations += 1;
        g.recal_lambda_shift = shift;
    }

    /// Set one worker's wear-ledger gauges (S22): cumulative die write
    /// pulses and the wear fraction of rated cycles. The vectors grow
    /// on demand — workers publish independently.
    pub fn set_worker_wear(&self, worker: usize, pulses: u64, wear: f64) {
        let mut g = self.inner.lock().unwrap();
        if g.wear_pulses.len() <= worker {
            g.wear_pulses.resize(worker + 1, 0);
            g.wear_fraction.resize(worker + 1, 0.0);
        }
        g.wear_pulses[worker] = pulses;
        g.wear_fraction[worker] = wear;
    }

    /// Account one caught worker panic (S21 supervision).
    pub fn record_worker_panic(&self) {
        self.inner.lock().unwrap().worker_panics += 1;
    }

    /// Account one worker replica rebuild after a caught panic.
    pub fn record_restart(&self) {
        self.inner.lock().unwrap().restarts += 1;
    }

    /// Account one frame refused at admission (queue cap / draining).
    pub fn record_shed_queue(&self) {
        self.inner.lock().unwrap().sheds_queue += 1;
    }

    /// Account one queued frame dropped at dequeue (S21 shed taxonomy).
    pub fn record_shed(&self, reason: ShedReason) {
        let mut g = self.inner.lock().unwrap();
        match reason {
            ShedReason::DeadlineExpired => g.sheds_deadline += 1,
            ShedReason::Draining => g.sheds_drain += 1,
            ShedReason::RestartBudget => g.sheds_restart += 1,
        }
    }

    /// Account one scrub tick skipped for deep ingress queues (S21
    /// idle-stealing scrub scheduling).
    pub fn record_scrub_skip(&self) {
        self.inner.lock().unwrap().scrubs_skipped += 1;
    }

    /// Set the degraded-worker gauge (the supervisor owns the count).
    pub fn set_degraded_workers(&self, n: u64) {
        self.inner.lock().unwrap().degraded_workers = n;
    }

    /// Fold the cumulative detached-pool-panic count (gauge, max —
    /// `util::pool::panics()` is process-global and monotonic).
    pub fn record_pool_panics(&self, n: u64) {
        let mut g = self.inner.lock().unwrap();
        g.pool_panics = g.pool_panics.max(n);
    }

    /// Account one well-formed request decoded off the wire (S23).
    pub fn record_wire_request(&self) {
        self.inner.lock().unwrap().wire_requests += 1;
    }

    /// Account one shed response written back to a remote client.
    pub fn record_wire_shed(&self) {
        self.inner.lock().unwrap().wire_sheds += 1;
    }

    /// Account one connection torn down without an orderly close.
    pub fn record_wire_disconnect(&self) {
        self.inner.lock().unwrap().wire_disconnects += 1;
    }

    /// Account one frame the codec rejected (S23 shed taxonomy for
    /// bytes: oversized prefix, bad UTF-8, parse failure, bad shape).
    pub fn record_wire_malformed(&self) {
        self.inner.lock().unwrap().wire_malformed += 1;
    }

    /// Store a windowed report (S21: workers publish periodic
    /// `snapshot_since` deltas from their idle ticks so an operator —
    /// or a test — can read the last window without a live request).
    pub fn store_window(&self, w: MetricsSnapshot) {
        self.inner.lock().unwrap().window = Some(w);
    }

    /// The last stored windowed report, if any worker published one.
    pub fn last_window(&self) -> Option<MetricsSnapshot> {
        self.inner.lock().unwrap().window.clone()
    }

    /// Derive the snapshot from an already-held guard — the one source
    /// of every rate/quantile, shared by `snapshot()` and `summary()`.
    fn snapshot_of(&self, g: &Inner) -> MetricsSnapshot {
        let secs = self.started.elapsed().as_secs_f64().max(1e-9);
        let lat = g.latency_us.stats();
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            macs: g.macs,
            uptime_s: secs,
            rps: g.requests as f64 / secs,
            macs_per_s: g.macs as f64 / secs,
            latency_mean_us: lat.mean,
            latency_p50_us: lat.p50,
            latency_p95_us: lat.p95,
            latency_p99_us: lat.p99,
            mean_batch: g.batch_sizes.mean(),
            latency: lat,
            batch: g.batch_sizes.stats(),
            active_rows: g.active_rows,
            row_slots: g.row_slots,
            energy_fj: g.energy_fj,
            noc_packets: g.noc_packets,
            noc_hops: g.noc_hops,
            tiles_used: g.tiles_used,
            tiles_total: g.tiles_total,
            flips_injected: g.flips_injected,
            flips_detected: g.flips_detected,
            flips_repaired: g.flips_repaired,
            scrubs: g.scrubs,
            scrub_energy_fj: g.scrub_energy_fj,
            scrub_busy_ns: g.scrub_busy_ns,
            sim_time_ns: g.sim_time_ns,
            recalibrations: g.recalibrations,
            recal_lambda_shift: g.recal_lambda_shift,
            wear_pulses: g.wear_pulses.clone(),
            wear_fraction: g.wear_fraction.clone(),
            spans: g
                .span_durs
                .iter()
                .map(|(name, h)| SpanStat {
                    name: (*name).to_string(),
                    count: h.count(),
                    p50_us: h.quantile(0.50),
                    p95_us: h.quantile(0.95),
                })
                .collect(),
            pool_queue_depth_hw: g.pool_queue_hw,
            trace_events: g.trace_events,
            trace_dropped: g.trace_dropped,
            worker_panics: g.worker_panics,
            restarts: g.restarts,
            sheds_queue: g.sheds_queue,
            sheds_deadline: g.sheds_deadline,
            sheds_drain: g.sheds_drain,
            sheds_restart: g.sheds_restart,
            scrubs_skipped: g.scrubs_skipped,
            degraded_workers: g.degraded_workers,
            pool_panics: g.pool_panics,
            wire_requests: g.wire_requests,
            wire_sheds: g.wire_sheds,
            wire_disconnects: g.wire_disconnects,
            wire_malformed: g.wire_malformed,
        }
    }

    /// Take one consistent snapshot (single lock acquisition).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        self.snapshot_of(&g)
    }

    /// Windowed snapshot since a previous one (S20 satellite): the
    /// returned rates cover only `now − prev`, so periodic reports
    /// from long-running servers stay meaningful. Take `prev` with
    /// [`snapshot`](Self::snapshot).
    pub fn snapshot_since(&self, prev: &MetricsSnapshot) -> MetricsSnapshot {
        self.snapshot().delta_since(prev)
    }

    /// Convenience: request count (one lock, via snapshot).
    pub fn requests(&self) -> u64 {
        self.snapshot().requests
    }

    /// Requests per second since startup.
    pub fn throughput_rps(&self) -> f64 {
        self.snapshot().rps
    }

    /// Human summary — rebuilt from [`MetricsSnapshot::to_json`] (S20
    /// satellite), so the text and the JSON artifact always agree.
    pub fn summary(&self) -> String {
        self.snapshot().summary_text()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(100.0);
        m.record_request(200.0);
        m.record_batch(2, 32768);
        assert_eq!(m.requests(), 2);
        let s = m.summary();
        assert!(s.contains("requests=2"));
        assert!(s.contains("macs=32768"));
        assert!(!s.contains("noc:"), "no fabric line off-fabric");
    }

    #[test]
    fn throughput_positive_after_requests() {
        let m = Metrics::new();
        m.record_request(1.0);
        assert!(m.throughput_rps() > 0.0);
    }

    #[test]
    fn snapshot_is_one_consistent_view() {
        let m = Metrics::new();
        for lat in [50.0, 150.0, 900.0] {
            m.record_request(lat);
        }
        m.record_batch(3, 3 * 16384);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 1);
        assert_eq!(s.macs, 3 * 16384);
        assert!(s.rps > 0.0 && s.macs_per_s > 0.0);
        assert!(s.latency_mean_us > 0.0);
        // Histogram upper-edge convention: p50 lands on a bucket bound.
        assert!(s.latency_p50_us >= 50.0);
        assert!(s.latency_p99_us >= s.latency_p50_us);
        assert!((s.mean_batch - 3.0).abs() < 1e-12);
        assert_eq!(s.noc_packets, 0);
        assert_eq!(s.tile_utilization(), 0.0);
        // The embedded digests agree with the flat quantile fields.
        assert_eq!(s.latency.p50, s.latency_p50_us);
        assert_eq!(s.latency.n, 3);
        assert_eq!(s.batch.n, 1);
    }

    #[test]
    fn activity_counters_and_density() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().input_density(), 0.0);
        m.record_activity(13, 128);
        m.record_activity(0, 128);
        let s = m.snapshot();
        assert_eq!(s.active_rows, 13);
        assert_eq!(s.row_slots, 256);
        assert!((s.input_density() - 13.0 / 256.0).abs() < 1e-12);
        assert!((m.input_density() - 13.0 / 256.0).abs() < 1e-12);
        assert!(m.summary().contains("active_rows=13 / 256"));
    }

    #[test]
    fn fresh_server_input_density_is_zero_not_nan() {
        // The S18 satellite fix: a fresh server (no traffic, zero row
        // slots) must report density 0.0 — finite, no NaN, no panic —
        // through both the snapshot and the Metrics convenience.
        let m = Metrics::new();
        let d = m.input_density();
        assert_eq!(d, 0.0);
        assert!(d.is_finite());
        assert_eq!(m.snapshot().input_density(), 0.0);
        assert_eq!(MetricsSnapshot::default().input_density(), 0.0);
        // Zero-slot activity records keep it well-defined too.
        m.record_activity(0, 0);
        assert_eq!(m.input_density(), 0.0);
    }

    #[test]
    fn energy_accumulates_and_shows_in_summary() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().energy_fj, 0.0);
        assert!(!m.summary().contains("energy:"), "no line before traffic");
        m.record_energy(1500.0);
        m.record_energy(500.0);
        m.record_request(10.0);
        let s = m.snapshot();
        assert_eq!(s.energy_fj, 2000.0);
        assert!(m.summary().contains("energy: 2.0 pJ modeled"));
    }

    #[test]
    fn reliability_counters_accumulate_and_show() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().scrub_duty_cycle(), 0.0);
        assert!(!m.summary().contains("reliability:"));
        m.record_fault_injection(12, 1e6);
        m.record_scrub(12, 12, 5_000.0, 2e5);
        m.record_fault_injection(3, 1e6);
        m.record_scrub(3, 3, 1_000.0, 2e5);
        let s = m.snapshot();
        assert_eq!(s.flips_injected, 15);
        assert_eq!(s.flips_detected, 15);
        assert_eq!(s.flips_repaired, 15);
        assert_eq!(s.scrubs, 2);
        assert_eq!(s.scrub_energy_fj, 6_000.0);
        assert!((s.scrub_duty_cycle() - 0.2).abs() < 1e-12);
        // Scrub energy is folded into the serving ledger.
        assert_eq!(s.energy_fj, 6_000.0);
        assert!(m.summary().contains(
            "reliability: flips injected=15 detected=15 repaired=15"
        ));
    }

    #[test]
    fn scrub_duty_cycle_clamps_at_one() {
        let m = Metrics::new();
        m.record_fault_injection(0, 10.0);
        m.record_scrub(0, 0, 0.0, 100.0);
        assert_eq!(m.snapshot().scrub_duty_cycle(), 1.0);
    }

    #[test]
    fn fabric_counters_and_gauges() {
        let m = Metrics::new();
        m.record_noc(10, 35);
        m.record_noc(5, 10);
        m.set_tile_usage(3, 4);
        let s = m.snapshot();
        assert_eq!(s.noc_packets, 15);
        assert_eq!(s.noc_hops, 45);
        assert_eq!(s.tiles_used, 3);
        assert!((s.tile_utilization() - 0.75).abs() < 1e-12);
        assert!((s.hops_per_packet() - 3.0).abs() < 1e-12);
        assert!(m.summary().contains("noc: packets=15 hops=45 tiles=3/4"));
    }

    #[test]
    fn summary_is_the_json_rendered() {
        // The satellite contract: summary() IS summary_from_json(
        // to_json()), and the JSON itself survives a text round-trip
        // through the vendored parser with the integral counters
        // intact.
        let m = Metrics::new();
        m.record_request(42.0);
        m.record_batch(4, 1000);
        m.record_activity(10, 100);
        m.record_energy(3000.0);
        m.record_noc(7, 21);
        m.set_tile_usage(2, 4);
        let s = m.snapshot();
        assert_eq!(
            m.summary(),
            MetricsSnapshot::summary_from_json(&s.to_json())
        );
        let back =
            json::parse(&s.to_json().to_string()).expect("round trip");
        assert_eq!(back.get("requests").and_then(Json::as_f64), Some(1.0));
        assert_eq!(back.get("macs").and_then(Json::as_f64), Some(1000.0));
        assert_eq!(
            back.get("noc")
                .and_then(|n| n.get("packets"))
                .and_then(Json::as_f64),
            Some(7.0)
        );
        assert_eq!(
            back.get("latency_us").and_then(|l| l.get("n")).and_then(
                Json::as_f64
            ),
            Some(1.0)
        );
        // Derived ratios ship in the JSON.
        assert_eq!(
            back.get("input_density").and_then(Json::as_f64),
            Some(0.1)
        );
    }

    #[test]
    fn snapshot_since_windows_the_rates() {
        let m = Metrics::new();
        m.record_request(10.0);
        m.record_batch(1, 100);
        let prev = m.snapshot();
        std::thread::sleep(std::time::Duration::from_millis(5));
        for _ in 0..3 {
            m.record_request(10.0);
        }
        m.record_batch(3, 900);
        let win = m.snapshot_since(&prev);
        assert_eq!(win.requests, 3);
        assert_eq!(win.batches, 1);
        assert_eq!(win.macs, 900);
        assert!(win.uptime_s > 0.0);
        assert!(
            (win.rps - 3.0 / win.uptime_s).abs() < 1e-9,
            "windowed rps {} over {}",
            win.rps,
            win.uptime_s
        );
        // The cumulative snapshot still sees everything.
        assert_eq!(m.snapshot().requests, 4);
        // Idle window: zero deltas, rates fall to zero.
        let prev2 = m.snapshot();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let idle = m.snapshot_since(&prev2);
        assert_eq!(idle.requests, 0);
        assert_eq!(idle.rps, 0.0);
    }

    #[test]
    fn supervision_counters_accumulate_and_show() {
        let m = Metrics::new();
        assert!(!m.summary().contains("supervision:"), "silent when zero");
        m.record_worker_panic();
        m.record_restart();
        m.record_shed_queue();
        m.record_shed(ShedReason::DeadlineExpired);
        m.record_shed(ShedReason::DeadlineExpired);
        m.record_shed(ShedReason::Draining);
        m.record_shed(ShedReason::RestartBudget);
        m.record_scrub_skip();
        m.set_degraded_workers(1);
        m.record_pool_panics(3);
        m.record_pool_panics(2); // gauge folds by max, never regresses
        m.record_request(10.0); // one served frame
        let s = m.snapshot();
        assert_eq!(s.worker_panics, 1);
        assert_eq!(s.restarts, 1);
        assert_eq!(s.sheds_queue, 1);
        assert_eq!(s.sheds_deadline, 2);
        assert_eq!(s.sheds_drain, 1);
        assert_eq!(s.sheds_restart, 1);
        assert_eq!(s.sheds_total(), 5);
        assert!((s.shed_rate() - 5.0 / 6.0).abs() < 1e-12);
        assert_eq!(s.scrubs_skipped, 1);
        assert_eq!(s.degraded_workers, 1);
        assert_eq!(s.pool_panics, 3);
        let txt = m.summary();
        assert!(
            txt.contains(
                "supervision: panics=1 restarts=1 sheds queue=1 \
                 deadline=2 drain=1 budget=1"
            ),
            "{txt}"
        );
        // The JSON carries the same numbers (summary is built from it).
        let j = s.to_json();
        let nest = |k: &str| {
            j.get("supervision")
                .and_then(|o| o.get(k))
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert_eq!(nest("sheds_total"), 5.0);
        assert_eq!(nest("degraded_workers"), 1.0);
        assert_eq!(nest("pool_panics"), 3.0);
    }

    #[test]
    fn endurance_gauges_accumulate_and_show() {
        let m = Metrics::new();
        assert!(!m.summary().contains("endurance:"), "silent when zero");
        assert_eq!(m.snapshot().wear_max(), 0.0);
        m.record_recalibration(0.12);
        m.record_recalibration(0.03); // gauge keeps the latest shift
        m.set_worker_wear(1, 500, 0.25); // out-of-order publish grows
        m.set_worker_wear(0, 100, 0.05);
        let s = m.snapshot();
        assert_eq!(s.recalibrations, 2);
        assert!((s.recal_lambda_shift - 0.03).abs() < 1e-12);
        assert_eq!(s.wear_pulses, vec![100, 500]);
        assert_eq!(s.wear_fraction, vec![0.05, 0.25]);
        assert!((s.wear_max() - 0.25).abs() < 1e-12);
        let txt = m.summary();
        assert!(txt.contains("endurance: recals=2"), "{txt}");
        // JSON carries the arrays and the derived max.
        let j = s.to_json();
        let e = j.get("endurance").expect("endurance section");
        assert_eq!(
            e.get("wear_max").and_then(Json::as_f64),
            Some(0.25)
        );
        assert_eq!(
            e.get("recalibrations").and_then(Json::as_f64),
            Some(2.0)
        );
        // Round-trips through the vendored parser.
        let back = json::parse(&j.to_string()).expect("round trip");
        assert_eq!(
            back.get("endurance")
                .and_then(|x| x.get("wear_max"))
                .and_then(Json::as_f64),
            Some(0.25)
        );
    }

    #[test]
    fn endurance_counters_window_and_gauges_stay_latest() {
        let m = Metrics::new();
        m.record_recalibration(0.5);
        m.set_worker_wear(0, 10, 0.01);
        let prev = m.snapshot();
        m.record_recalibration(0.2);
        m.set_worker_wear(0, 20, 0.02);
        let w = m.snapshot_since(&prev);
        assert_eq!(w.recalibrations, 1, "windowed, not cumulative");
        assert!((w.recal_lambda_shift - 0.2).abs() < 1e-12, "latest gauge");
        assert_eq!(w.wear_pulses, vec![20], "wear ledger is latest-view");
        assert_eq!(w.wear_fraction, vec![0.02]);
    }

    #[test]
    fn supervision_counters_window_like_counters() {
        let m = Metrics::new();
        m.record_shed(ShedReason::Draining);
        m.record_worker_panic();
        m.set_degraded_workers(1);
        let prev = m.snapshot();
        m.record_shed(ShedReason::Draining);
        m.record_shed_queue();
        m.record_restart();
        let w = m.snapshot_since(&prev);
        assert_eq!(w.sheds_drain, 1, "windowed, not cumulative");
        assert_eq!(w.sheds_queue, 1);
        assert_eq!(w.restarts, 1);
        assert_eq!(w.worker_panics, 0);
        // Gauges stay latest-view.
        assert_eq!(w.degraded_workers, 1);
    }

    #[test]
    fn windowed_reports_store_and_read_back() {
        let m = Metrics::new();
        assert!(m.last_window().is_none());
        m.record_request(5.0);
        let prev = MetricsSnapshot::default();
        m.store_window(m.snapshot_since(&prev));
        let w = m.last_window().expect("stored");
        assert_eq!(w.requests, 1);
        // Overwrite keeps only the latest window.
        m.record_request(5.0);
        m.store_window(m.snapshot_since(&prev));
        assert_eq!(m.last_window().unwrap().requests, 2);
    }

    #[test]
    fn wire_counters_accumulate_window_and_show() {
        let m = Metrics::new();
        assert!(!m.summary().contains("net:"), "silent when zero");
        m.record_wire_request();
        m.record_wire_request();
        m.record_wire_shed();
        m.record_wire_disconnect();
        m.record_wire_malformed();
        let s = m.snapshot();
        assert_eq!(s.wire_requests, 2);
        assert_eq!(s.wire_sheds, 1);
        assert_eq!(s.wire_disconnects, 1);
        assert_eq!(s.wire_malformed, 1);
        let txt = m.summary();
        assert!(
            txt.contains(
                "net: wire_requests=2 sheds=1 disconnects=1 malformed=1"
            ),
            "{txt}"
        );
        // The JSON section carries the same numbers and round-trips.
        let j = s.to_json();
        let back = json::parse(&j.to_string()).expect("round trip");
        let nest = |k: &str| {
            back.get("net")
                .and_then(|o| o.get(k))
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert_eq!(nest("wire_requests"), 2.0);
        assert_eq!(nest("wire_sheds"), 1.0);
        assert_eq!(nest("wire_disconnects"), 1.0);
        assert_eq!(nest("wire_malformed"), 1.0);
        // Windowed view differences like every other counter.
        let prev = m.snapshot();
        m.record_wire_request();
        m.record_wire_malformed();
        let w = m.snapshot_since(&prev);
        assert_eq!(w.wire_requests, 1);
        assert_eq!(w.wire_sheds, 0);
        assert_eq!(w.wire_malformed, 1);
    }

    #[test]
    fn absorb_trace_folds_span_gauges() {
        use crate::obs::{TraceEvent, TraceKind, TraceReport};
        let m = Metrics::new();
        let report = TraceReport {
            events: vec![
                TraceEvent {
                    ts_ns: 10,
                    dur_ns: 5_000,
                    kind: TraceKind::MacroMvm,
                    stage: 0,
                    worker: 0,
                    payload: [16.0, 1.0],
                },
                TraceEvent {
                    ts_ns: 20,
                    dur_ns: 7_000,
                    kind: TraceKind::MacroMvm,
                    stage: 0,
                    worker: 1,
                    payload: [8.0, 2.0],
                },
                TraceEvent {
                    ts_ns: 30,
                    dur_ns: 0,
                    kind: TraceKind::QueueDepth,
                    stage: 0,
                    worker: 0,
                    payload: [9.0, 0.0],
                },
            ],
            dropped: 2,
            threads: vec!["main".into()],
        };
        m.absorb_trace(&report);
        m.record_pool_queue_depth(4); // lower than the counter sample
        let s = m.snapshot();
        assert_eq!(s.trace_events, 3);
        assert_eq!(s.trace_dropped, 2);
        assert_eq!(s.pool_queue_depth_hw, 9);
        assert_eq!(s.spans.len(), 1);
        assert_eq!(s.spans[0].name, "macro.mvm");
        assert_eq!(s.spans[0].count, 2);
        assert!(s.spans[0].p50_us > 0.0);
        assert!(s.spans[0].p95_us >= s.spans[0].p50_us);
        let txt = m.summary();
        assert!(txt.contains("trace: events=3 dropped=2"), "{txt}");
        assert!(txt.contains("span macro.mvm: n=2"), "{txt}");
    }
}

//! Request batcher (DESIGN.md S11): collects single-vector MVM requests
//! into batches for the PJRT backend (whose artifacts have fixed batch
//! shapes) — close a batch when full or when the oldest request exceeds
//! the timeout. The serving loop in `server.rs` drives it; it also runs
//! standalone in virtual time for the scheduler benches.

/// One queued request.
#[derive(Debug, Clone)]
pub struct Request<T> {
    pub id: u64,
    pub payload: T,
    pub arrived_ns: f64,
}

/// A closed batch.
#[derive(Debug, Clone)]
pub struct Batch<T> {
    pub requests: Vec<Request<T>>,
    pub closed_at_ns: f64,
    /// Why the batch closed.
    pub reason: CloseReason,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CloseReason {
    Full,
    Timeout,
    Flush,
}

/// Size-or-timeout batcher.
#[derive(Debug)]
pub struct Batcher<T> {
    pub max_batch: usize,
    pub timeout_ns: f64,
    pending: Vec<Request<T>>,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, timeout_ns: f64) -> Self {
        assert!(max_batch > 0);
        Batcher {
            max_batch,
            timeout_ns,
            pending: Vec::new(),
        }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Add a request; returns a batch if this filled it.
    pub fn push(&mut self, req: Request<T>, now_ns: f64) -> Option<Batch<T>> {
        self.pending.push(req);
        if self.pending.len() >= self.max_batch {
            Some(self.close(now_ns, CloseReason::Full))
        } else {
            None
        }
    }

    /// Check the timeout; returns a batch if the oldest request expired.
    pub fn poll(&mut self, now_ns: f64) -> Option<Batch<T>> {
        let oldest = self.pending.first()?.arrived_ns;
        if now_ns - oldest >= self.timeout_ns {
            Some(self.close(now_ns, CloseReason::Timeout))
        } else {
            None
        }
    }

    /// Time at which the current batch will expire (for sleep scheduling).
    pub fn deadline_ns(&self) -> Option<f64> {
        self.pending.first().map(|r| r.arrived_ns + self.timeout_ns)
    }

    /// Force-close whatever is pending.
    pub fn flush(&mut self, now_ns: f64) -> Option<Batch<T>> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.close(now_ns, CloseReason::Flush))
        }
    }

    fn close(&mut self, now_ns: f64, reason: CloseReason) -> Batch<T> {
        Batch {
            requests: std::mem::take(&mut self.pending),
            closed_at_ns: now_ns,
            reason,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: f64) -> Request<u32> {
        Request {
            id,
            payload: id as u32,
            arrived_ns: t,
        }
    }

    #[test]
    fn closes_when_full() {
        let mut b = Batcher::new(3, 1000.0);
        assert!(b.push(req(0, 0.0), 0.0).is_none());
        assert!(b.push(req(1, 1.0), 1.0).is_none());
        let batch = b.push(req(2, 2.0), 2.0).unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.reason, CloseReason::Full);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn closes_on_timeout() {
        let mut b = Batcher::new(8, 100.0);
        b.push(req(0, 0.0), 0.0);
        b.push(req(1, 50.0), 50.0);
        assert!(b.poll(99.0).is_none());
        let batch = b.poll(100.0).unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.reason, CloseReason::Timeout);
    }

    #[test]
    fn deadline_tracks_oldest() {
        let mut b = Batcher::new(8, 100.0);
        assert!(b.deadline_ns().is_none());
        b.push(req(0, 10.0), 10.0);
        b.push(req(1, 90.0), 90.0);
        assert_eq!(b.deadline_ns(), Some(110.0));
    }

    #[test]
    fn flush_returns_partial_batch() {
        let mut b = Batcher::new(8, 100.0);
        b.push(req(0, 0.0), 0.0);
        let batch = b.flush(5.0).unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.reason, CloseReason::Flush);
        assert!(b.flush(6.0).is_none());
    }

    #[test]
    fn order_preserved_within_batch() {
        let mut b = Batcher::new(4, 100.0);
        for i in 0..3 {
            b.push(req(i, i as f64), i as f64);
        }
        let batch = b.push(req(3, 3.0), 3.0).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }
}

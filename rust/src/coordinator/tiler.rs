//! Weight-matrix tiling: maps an arbitrary (K×N) 2-bit code matrix onto
//! 128×128 macro tiles (DESIGN.md S11). Rows beyond K / cols beyond N are
//! padded with code 0 — *not* zero conductance (the device has no zero
//! state), so consumers must mask padded columns and subtract the offset
//! row term for padded rows, which the signed-weight offset scheme in
//! `snn::quant` does anyway.

/// A (K×N) matrix of 2-bit codes split into row-major macro tiles.
#[derive(Debug, Clone)]
pub struct TiledMatrix {
    pub k: usize,
    pub n: usize,
    pub tile: usize,
    pub row_tiles: usize,
    pub col_tiles: usize,
    /// Tile (i, j) at `tiles[i * col_tiles + j]`, row-major tile×tile codes.
    tiles: Vec<Vec<u8>>,
}

impl TiledMatrix {
    /// Split `codes` (row-major K×N) into `tile`×`tile` blocks.
    pub fn new(codes: &[u8], k: usize, n: usize, tile: usize) -> Self {
        assert_eq!(codes.len(), k * n, "code matrix shape");
        assert!(tile > 0);
        let row_tiles = k.div_ceil(tile);
        let col_tiles = n.div_ceil(tile);
        let mut tiles = Vec::with_capacity(row_tiles * col_tiles);
        for ti in 0..row_tiles {
            for tj in 0..col_tiles {
                let mut block = vec![0u8; tile * tile];
                for r in 0..tile {
                    let src_r = ti * tile + r;
                    if src_r >= k {
                        break;
                    }
                    for c in 0..tile {
                        let src_c = tj * tile + c;
                        if src_c >= n {
                            break;
                        }
                        block[r * tile + c] = codes[src_r * n + src_c];
                    }
                }
                tiles.push(block);
            }
        }
        TiledMatrix {
            k,
            n,
            tile,
            row_tiles,
            col_tiles,
            tiles,
        }
    }

    pub fn num_tiles(&self) -> usize {
        self.tiles.len()
    }

    pub fn tile_codes(&self, ti: usize, tj: usize) -> &[u8] {
        &self.tiles[ti * self.col_tiles + tj]
    }

    pub fn tile_codes_flat(&self, idx: usize) -> &[u8] {
        &self.tiles[idx]
    }

    /// Split an input vector (len K) into per-row-tile padded slices.
    pub fn split_input(&self, x: &[u32]) -> Vec<Vec<u32>> {
        assert_eq!(x.len(), self.k, "input length");
        (0..self.row_tiles)
            .map(|ti| {
                let mut part = vec![0u32; self.tile];
                let lo = ti * self.tile;
                let hi = ((ti + 1) * self.tile).min(self.k);
                part[..hi - lo].copy_from_slice(&x[lo..hi]);
                part
            })
            .collect()
    }

    /// Append one item's per-row-tile slices (zero-padded to `tile`,
    /// exactly like [`split_input`](Self::split_input)) onto reusable
    /// flat batch buffers — `bufs[ti]` grows by `tile` values per call
    /// (DESIGN.md S17: no per-item `Vec` allocations on the hot path).
    pub fn split_input_into(&self, x: &[u32], bufs: &mut [Vec<u32>]) {
        assert_eq!(x.len(), self.k, "input length");
        assert_eq!(bufs.len(), self.row_tiles, "one buffer per row tile");
        for (ti, buf) in bufs.iter_mut().enumerate() {
            let lo = ti * self.tile;
            let hi = ((ti + 1) * self.tile).min(self.k);
            buf.extend_from_slice(&x[lo..hi]);
            buf.resize(buf.len() + (self.tile - (hi - lo)), 0);
        }
    }

    /// Accumulate per-tile MAC outputs back into a length-N result:
    /// `partials[ti][tj]` is the tile's `tile`-wide column output.
    pub fn accumulate(&self, partials: &[Vec<Vec<f64>>]) -> Vec<f64> {
        assert_eq!(partials.len(), self.row_tiles);
        let mut y = vec![0.0f64; self.n];
        for row in partials {
            assert_eq!(row.len(), self.col_tiles);
            for (tj, part) in row.iter().enumerate() {
                assert_eq!(part.len(), self.tile);
                let lo = tj * self.tile;
                let hi = ((tj + 1) * self.tile).min(self.n);
                for (c, &v) in part[..hi - lo].iter().enumerate() {
                    y[lo + c] += v;
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_multiple_tiling() {
        let codes: Vec<u8> = (0..256 * 128).map(|i| (i % 4) as u8).collect();
        let tm = TiledMatrix::new(&codes, 256, 128, 128);
        assert_eq!(tm.row_tiles, 2);
        assert_eq!(tm.col_tiles, 1);
        assert_eq!(tm.num_tiles(), 2);
        // spot check: tile (1,0) row 0 == source row 128
        let t = tm.tile_codes(1, 0);
        for c in 0..128 {
            assert_eq!(t[c], codes[128 * 128 + c]);
        }
    }

    #[test]
    fn ragged_tiling_pads_with_zero_code() {
        let k = 130;
        let n = 10;
        let codes = vec![3u8; k * n];
        let tm = TiledMatrix::new(&codes, k, n, 128);
        assert_eq!(tm.row_tiles, 2);
        assert_eq!(tm.col_tiles, 1);
        let t = tm.tile_codes(1, 0);
        assert_eq!(t[0], 3); // real row 128
        assert_eq!(t[1 * 128 + 0], 3); // real row 129
        assert_eq!(t[2 * 128 + 0], 0); // padding
        assert_eq!(t[0 * 128 + 10], 0); // padded column
    }

    #[test]
    fn split_input_pads() {
        let codes = vec![0u8; 130 * 10];
        let tm = TiledMatrix::new(&codes, 130, 10, 128);
        let x: Vec<u32> = (0..130).collect();
        let parts = tm.split_input(&x);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0][127], 127);
        assert_eq!(parts[1][0], 128);
        assert_eq!(parts[1][2], 0); // padding
    }

    #[test]
    fn split_input_into_matches_split_input_per_item() {
        let codes = vec![0u8; 130 * 10];
        let tm = TiledMatrix::new(&codes, 130, 10, 128);
        let xs: Vec<Vec<u32>> = (0..3)
            .map(|i| (0..130u32).map(|v| v * (i + 1)).collect())
            .collect();
        let mut bufs: Vec<Vec<u32>> = vec![Vec::new(); 2];
        for x in &xs {
            tm.split_input_into(x, &mut bufs);
        }
        for (b, x) in xs.iter().enumerate() {
            let want = tm.split_input(x);
            for ti in 0..2 {
                assert_eq!(
                    &bufs[ti][b * 128..(b + 1) * 128],
                    want[ti].as_slice(),
                    "item {b} tile {ti}"
                );
            }
        }
    }

    #[test]
    fn accumulate_sums_row_tiles_and_trims_cols() {
        let codes = vec![0u8; 256 * 100];
        let tm = TiledMatrix::new(&codes, 256, 100, 128);
        let part = vec![1.0f64; 128];
        let partials = vec![vec![part.clone()], vec![part.clone()]];
        let y = tm.accumulate(&partials);
        assert_eq!(y.len(), 100);
        assert!(y.iter().all(|&v| (v - 2.0).abs() < 1e-12));
    }

    #[test]
    fn tiled_mvm_equals_dense_mvm() {
        // End-to-end: tile a 300×200 matrix, run ideal per-tile MACs,
        // accumulate, compare against the dense oracle.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(17);
        let (k, n, tile) = (300, 200, 128);
        let codes: Vec<u8> = (0..k * n).map(|_| rng.below(4) as u8).collect();
        let x: Vec<u32> = (0..k).map(|_| rng.below(256) as u32).collect();
        let levels = crate::config::LevelMap::DeviceTrue.levels();

        // dense oracle
        let mut want = vec![0.0f64; n];
        for r in 0..k {
            for c in 0..n {
                want[c] += x[r] as f64 * levels[codes[r * n + c] as usize];
            }
        }

        let tm = TiledMatrix::new(&codes, k, n, tile);
        let xparts = tm.split_input(&x);
        let mut partials = Vec::new();
        for ti in 0..tm.row_tiles {
            let mut row = Vec::new();
            for tj in 0..tm.col_tiles {
                let tc = tm.tile_codes(ti, tj);
                let mut part = vec![0.0f64; tile];
                for r in 0..tile {
                    let xv = xparts[ti][r] as f64;
                    if xv == 0.0 {
                        continue;
                    }
                    for c in 0..tile {
                        part[c] += xv * levels[tc[r * tile + c] as usize];
                    }
                }
                row.push(part);
            }
            partials.push(row);
        }
        let got = tm.accumulate(&partials);
        // Padded rows contribute x=0; padded cols trimmed. But padded
        // rows' code-0 cells have *nonzero G* — x=0 keeps them silent.
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }
}

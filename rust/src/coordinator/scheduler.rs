//! Event-driven tile scheduler (DESIGN.md S11) — the system-level face of
//! the paper's contribution: macros are *activated only by arriving work*
//! (spike events), weights stay resident (weight-stationary affinity), and
//! completion is signalled by the macros' own output events, not a clock.
//!
//! The scheduler runs in virtual time: each worker macro advances its own
//! clock by the *simulated analog latency* of the ops it executes (charge
//! window + compare phase from `MacroResult::latency_ns`), plus a
//! reprogramming penalty when a different weight tile must be loaded.

use crate::config::MacroConfig;
use crate::energy::EnergyBreakdown;
use crate::macro_model::CimMacro;

use super::tiler::TiledMatrix;

/// One unit of work: apply input slice `x` to weight tile `tile_idx`.
#[derive(Debug, Clone)]
pub struct TileOp {
    pub tile_idx: usize,
    pub x: Vec<u32>,
    /// Arrival time in virtual ns (0 for batch jobs).
    pub arrival_ns: f64,
}

/// Dispatch policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Cyclic assignment, ignores state.
    RoundRobin,
    /// Pick the earliest-free worker.
    LeastLoaded,
    /// Prefer a worker already programmed with the op's tile (weight-
    /// stationary), falling back to earliest-free.
    TileAffinity,
}

/// Per-worker statistics.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    pub ops: u64,
    pub reprograms: u64,
    pub busy_ns: f64,
}

/// Outcome of scheduling a batch of tile ops.
#[derive(Debug)]
pub struct ScheduleReport {
    /// Per-op column outputs, in op order.
    pub results: Vec<Vec<f64>>,
    /// Per-op completion times (virtual ns).
    pub completions_ns: Vec<f64>,
    pub makespan_ns: f64,
    pub energy: EnergyBreakdown,
    pub worker_stats: Vec<WorkerStats>,
    /// Total reprogramming events across workers.
    pub reprograms: u64,
}

struct Worker {
    cim: CimMacro,
    programmed: Option<usize>,
    free_at_ns: f64,
    stats: WorkerStats,
}

/// A pool of macro workers executing tile ops in virtual time.
pub struct Scheduler {
    workers: Vec<Worker>,
    policy: Policy,
    rr_next: usize,
    /// Write latency to reprogram a full tile (ns). SOT write ~2 ns/row
    /// pair ×128 rows with verify ≈ 500 ns (DESIGN.md §7).
    pub reprogram_ns: f64,
    /// Reprogram write energy per tile (fJ): 16384 cells × 2 junctions ×
    /// I²R·t (device::write defaults) — charged to control.
    pub reprogram_fj: f64,
}

impl Scheduler {
    pub fn new(cfg: &MacroConfig, n_workers: usize, policy: Policy) -> Self {
        assert!(n_workers > 0);
        let workers = (0..n_workers)
            .map(|_| Worker {
                cim: CimMacro::new(cfg.clone()),
                programmed: None,
                free_at_ns: 0.0,
                stats: WorkerStats::default(),
            })
            .collect();
        Scheduler {
            workers,
            policy,
            rr_next: 0,
            reprogram_ns: 500.0,
            reprogram_fj: 16384.0 * 2.0 * 7200.0, // 60 µA², 1 kΩ, 2 ns
        }
    }

    fn pick_worker(&mut self, tile_idx: usize) -> usize {
        match self.policy {
            Policy::RoundRobin => {
                let w = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.workers.len();
                w
            }
            Policy::LeastLoaded => self
                .workers
                .iter()
                .enumerate()
                .min_by(|a, b| {
                    a.1.free_at_ns.partial_cmp(&b.1.free_at_ns).unwrap()
                })
                .map(|(i, _)| i)
                .unwrap(),
            Policy::TileAffinity => {
                // Prefer a worker already holding the tile — but spill to
                // the earliest-free worker when waiting for the affine one
                // would cost more than a reprogram (work-conserving).
                let affine = self
                    .workers
                    .iter()
                    .enumerate()
                    .filter(|(_, w)| w.programmed == Some(tile_idx))
                    .min_by(|a, b| {
                        a.1.free_at_ns.partial_cmp(&b.1.free_at_ns).unwrap()
                    })
                    .map(|(i, w)| (i, w.free_at_ns));
                let free = self
                    .workers
                    .iter()
                    .enumerate()
                    .min_by(|a, b| {
                        a.1.free_at_ns.partial_cmp(&b.1.free_at_ns).unwrap()
                    })
                    .map(|(i, w)| (i, w.free_at_ns))
                    .unwrap();
                match affine {
                    Some((ai, at)) if at - free.1 <= self.reprogram_ns => ai,
                    _ => free.0,
                }
            }
        }
    }

    /// Execute `ops` against `weights`, returning results + metrics.
    pub fn run(&mut self, weights: &TiledMatrix, ops: &[TileOp]) -> ScheduleReport {
        let mut results = Vec::with_capacity(ops.len());
        let mut completions = Vec::with_capacity(ops.len());
        let mut energy = EnergyBreakdown::default();
        let mut reprograms = 0u64;

        for op in ops {
            let wi = self.pick_worker(op.tile_idx);
            let w = &mut self.workers[wi];
            let mut start = w.free_at_ns.max(op.arrival_ns);
            if w.programmed != Some(op.tile_idx) {
                w.cim.program(weights.tile_codes_flat(op.tile_idx));
                w.programmed = Some(op.tile_idx);
                start += self.reprogram_ns;
                w.stats.reprograms += 1;
                reprograms += 1;
                energy.control_fj += self.reprogram_fj;
            }
            let r = w.cim.mvm(&op.x);
            let done = start + r.latency_ns;
            w.free_at_ns = done;
            w.stats.ops += 1;
            w.stats.busy_ns += r.latency_ns;
            energy.add(&r.energy);
            results.push(r.y_mac);
            completions.push(done);
        }

        let makespan = completions.iter().cloned().fold(0.0, f64::max);
        ScheduleReport {
            results,
            completions_ns: completions,
            makespan_ns: makespan,
            energy,
            worker_stats: self.workers.iter().map(|w| w.stats.clone()).collect(),
            reprograms,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn weights_and_ops(
        n_tiles_rows: usize,
        ops_per_tile: usize,
        seed: u64,
    ) -> (TiledMatrix, Vec<TileOp>) {
        let mut rng = Rng::new(seed);
        let k = 128 * n_tiles_rows;
        let n = 128;
        let codes: Vec<u8> = (0..k * n).map(|_| rng.below(4) as u8).collect();
        let tm = TiledMatrix::new(&codes, k, n, 128);
        let mut ops = Vec::new();
        for t in 0..tm.num_tiles() {
            for _ in 0..ops_per_tile {
                ops.push(TileOp {
                    tile_idx: t,
                    x: (0..128).map(|_| rng.below(256) as u32).collect(),
                    arrival_ns: 0.0,
                });
            }
        }
        (tm, ops)
    }

    #[test]
    fn results_are_policy_invariant() {
        let (tm, ops) = weights_and_ops(2, 3, 21);
        let cfg = MacroConfig::default();
        let mut outs = Vec::new();
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::TileAffinity] {
            let mut s = Scheduler::new(&cfg, 3, policy);
            outs.push(s.run(&tm, &ops).results);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
    }

    #[test]
    fn tile_affinity_reduces_reprogramming() {
        // Interleave ops over two tiles so that round-robin thrashes the
        // weight arrays (3 workers, 2 tiles → phase mismatch) while the
        // affinity policy keeps workers pinned.
        let (tm, ops_seq) = weights_and_ops(2, 8, 22);
        // original order: tile0 ×8 then tile1 ×8 → interleave t0,t1,t0,…
        let mut ops = Vec::with_capacity(ops_seq.len());
        for i in 0..8 {
            ops.push(ops_seq[i].clone());
            ops.push(ops_seq[8 + i].clone());
        }
        let cfg = MacroConfig::default();
        let mut rr = Scheduler::new(&cfg, 3, Policy::RoundRobin);
        let mut aff = Scheduler::new(&cfg, 3, Policy::TileAffinity);
        let r_rr = rr.run(&tm, &ops);
        let r_aff = aff.run(&tm, &ops);
        assert!(
            r_aff.reprograms < r_rr.reprograms / 2,
            "affinity {} vs rr {}",
            r_aff.reprograms,
            r_rr.reprograms
        );
        // And it shows up as makespan.
        assert!(r_aff.makespan_ns <= r_rr.makespan_ns);
    }

    #[test]
    fn more_workers_shrink_makespan() {
        let (tm, ops) = weights_and_ops(1, 16, 23);
        let cfg = MacroConfig::default();
        let mut one = Scheduler::new(&cfg, 1, Policy::LeastLoaded);
        let mut four = Scheduler::new(&cfg, 4, Policy::TileAffinity);
        let m1 = one.run(&tm, &ops).makespan_ns;
        let m4 = four.run(&tm, &ops).makespan_ns;
        assert!(m4 < m1 / 2.0, "1w {m1} vs 4w {m4}");
    }

    #[test]
    fn energy_accumulates_across_ops() {
        let (tm, ops) = weights_and_ops(1, 4, 24);
        let cfg = MacroConfig::default();
        let mut s = Scheduler::new(&cfg, 2, Policy::TileAffinity);
        let r = s.run(&tm, &ops);
        // 4 MVMs ≈ 4 × ~134 pJ plus reprogram energy.
        assert!(r.energy.total_pj() > 400.0);
        let ops_done: u64 = r.worker_stats.iter().map(|w| w.ops).sum();
        assert_eq!(ops_done, 4);
    }

    #[test]
    fn arrival_times_respected() {
        let (tm, mut ops) = weights_and_ops(1, 2, 25);
        ops[1].arrival_ns = 1e6;
        let cfg = MacroConfig::default();
        let mut s = Scheduler::new(&cfg, 2, Policy::LeastLoaded);
        let r = s.run(&tm, &ops);
        assert!(r.completions_ns[1] > 1e6);
    }
}

//! L3 coordinator (DESIGN.md S11): weight tiling, the event-driven tile
//! scheduler with weight-stationary affinity, request batching, the
//! serving loop, and metrics. This is the layer a downstream user calls;
//! everything below it (macro, circuits, devices) is substrate.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod scheduler;
pub mod scrub;
pub mod server;
pub mod supervisor;
pub mod tiler;

pub use batcher::{Batch, Batcher, CloseReason, Request};
pub use metrics::{Metrics, MetricsSnapshot, SpanStat};
pub use pipeline::{pipeline_makespan_ns, serial_makespan_ns, ThreadedPipeline};
pub use scheduler::{Policy, ScheduleReport, Scheduler, TileOp};
pub use scrub::{EndurancePolicy, MissionClock, ScrubPolicy, Scrubber};
pub use server::{BackendKind, MacroServer, Router, ServerConfig};
pub use supervisor::{
    Admission, ChaosPlan, RestartPolicy, ShedReason, StatusMsg, Supervisor,
    Verdict,
};
pub use tiler::TiledMatrix;

//! Retention scrub scheduler (DESIGN.md S11 × retention extension, S19):
//! for weight-stationary deployments the coordinator must periodically
//! re-verify/refresh the programmed codes before Néel relaxation corrupts
//! them. This module computes the scrub schedule from the device's
//! retention parameters, accounts the resulting energy/availability tax
//! against the macro's budget, and — since S19 — drives a live
//! background [`Scrubber`] on the shared `util::pool` that steals idle
//! array time between serving work.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::device::retention::{EnduranceParams, RetentionParams};
use crate::obs::{self, TraceKind};
use crate::util::pool;

/// Scrub policy for one macro.
#[derive(Debug, Clone, Copy)]
pub struct ScrubPolicy {
    /// Target per-junction flip probability between scrubs.
    pub p_target: f64,
    /// Time to scrub one full tile (read-verify-rewrite, ns).
    pub scrub_duration_ns: f64,
    /// Energy per full-tile scrub (fJ).
    pub scrub_energy_fj: f64,
    /// Idle-stealing gate (DESIGN.md S21): skip a scrub tick while the
    /// total ingress queue depth exceeds this many frames. Retention is
    /// a milliseconds-to-days phenomenon, so deferring one tick under
    /// load is free; serving latency under overload is not.
    pub queue_depth_threshold: usize,
}

impl ScrubPolicy {
    /// Defaults: verify+selective-rewrite of a 128×128 tile. Reads are
    /// nearly free; energy is dominated by the expected rewrites.
    pub fn standard() -> Self {
        ScrubPolicy {
            p_target: 1e-9,
            scrub_duration_ns: 100_000.0, // 0.1 ms per tile
            scrub_energy_fj: 2.0e6,       // ~2 µJ: sparse rewrites
            queue_depth_threshold: 4,
        }
    }

    /// Idle-stealing decision: `true` when a scrub tick should be
    /// deferred because `queue_depth` frames are waiting for service.
    /// The skip must be *counted* by the caller
    /// (`Metrics::record_scrub_skip`) so `scrub_duty_cycle()` — which is
    /// derived from scrubs actually executed — stays correct.
    pub fn should_skip(&self, queue_depth: usize) -> bool {
        queue_depth > self.queue_depth_threshold
    }

    /// Scrub interval for the given device corner (ns).
    pub fn interval_ns(&self, ret: &RetentionParams) -> f64 {
        ret.scrub_interval_ns(self.p_target)
    }

    /// Fraction of wall time spent scrubbing (availability tax).
    pub fn duty_cycle(&self, ret: &RetentionParams) -> f64 {
        self.scrub_duration_ns
            / (self.scrub_duration_ns + self.interval_ns(ret))
    }

    /// Average scrub power (µW = fJ/ns) amortized over the interval.
    pub fn average_power_uw(&self, ret: &RetentionParams) -> f64 {
        self.scrub_energy_fj / self.interval_ns(ret)
    }

    /// Relative efficiency loss when the macro runs `mvm_rate_per_s`
    /// MVMs/s at `e_mvm_fj` each: scrub energy / compute energy.
    pub fn efficiency_tax(
        &self,
        ret: &RetentionParams,
        mvm_rate_per_s: f64,
        e_mvm_fj: f64,
    ) -> f64 {
        let compute_uw = e_mvm_fj * mvm_rate_per_s * 1e-9; // fJ/s → µW
        if compute_uw <= 0.0 {
            return f64::INFINITY;
        }
        self.average_power_uw(ret) / compute_uw
    }
}

/// Wear-budget SLO (DESIGN.md S22): how aggressively a worker may keep
/// scrubbing as its die consumes rated write cycles. Scrubbing repairs
/// retention flips but *costs* endurance — every rewrite is a real SOT
/// pulse — so the policy trades refresh frequency against die life:
///
/// * below `throttle_start` wear: scrub every tick (nominal schedule);
/// * between `throttle_start` and `wear_ceiling`: the effective scrub
///   interval stretches linearly up to `max_stretch` ticks — the die
///   is rationed, accepting more residual flips to slow the burn;
/// * at or past `wear_ceiling`: the worker must stop scrubbing and
///   degrade through the S21 `Degraded` path — a worn-out die is an
///   operational event, not something to silently keep burning.
#[derive(Debug, Clone, Copy)]
pub struct EndurancePolicy {
    /// Rated write cycles of the die (per-junction rating applied to
    /// the array's aggregate pulse counter).
    pub endurance: EnduranceParams,
    /// Wear fraction where scrub throttling begins.
    pub throttle_start: f64,
    /// Wear fraction where the worker degrades and scrubbing stops.
    pub wear_ceiling: f64,
    /// Scrub-interval stretch factor reached at the ceiling (ticks).
    pub max_stretch: f64,
}

impl EndurancePolicy {
    /// Defaults: start rationing at half the rated life, degrade at
    /// 90 %, stretch the scrub interval up to 8× in between.
    pub fn standard() -> Self {
        EndurancePolicy {
            endurance: EnduranceParams::default(),
            throttle_start: 0.5,
            wear_ceiling: 0.9,
            max_stretch: 8.0,
        }
    }

    /// Wear fraction for an aggregate pulse count (saturates at 1).
    pub fn wear(&self, write_pulses: u64) -> f64 {
        self.endurance.wear(write_pulses)
    }

    /// Scrub-interval stretch at `wear`: 1 below `throttle_start`,
    /// linear up to `max_stretch` at the ceiling, `max_stretch` past it.
    pub fn stretch(&self, wear: f64) -> f64 {
        if wear <= self.throttle_start {
            return 1.0;
        }
        let span = (self.wear_ceiling - self.throttle_start).max(1e-12);
        let frac = ((wear - self.throttle_start) / span).min(1.0);
        1.0 + frac * (self.max_stretch - 1.0)
    }

    /// Deterministic tick gate: with the interval stretched to
    /// `stretch(wear)` ticks, scrub on rounds 0, s, 2s, … — derived
    /// from the round counter, not wall time, so two arms with the
    /// same wear trajectory make identical decisions.
    pub fn scrub_this_round(&self, wear: f64, round: u64) -> bool {
        if self.should_degrade(wear) {
            return false;
        }
        let s = self.stretch(wear).round().max(1.0) as u64;
        round % s == 0
    }

    /// Past the ceiling the worker must degrade instead of scrubbing.
    pub fn should_degrade(&self, wear: f64) -> bool {
        wear >= self.wear_ceiling
    }
}

/// Background scrub driver (DESIGN.md S19): a detached task on the
/// shared worker pool that calls `tick(round)` every `period` of wall
/// time until stopped. The tick typically broadcasts scrub jobs into
/// the stream server's per-worker FIFOs — the jobs then *interleave*
/// with frames at session granularity, which is how the scrubber
/// "steals idle array time" without ever racing a frame on the same
/// model state.
///
/// [`stop`](Scrubber::stop) quiesces: it returns only after the loop
/// has exited, so no tick is in flight afterwards (the guarantee the
/// scrub-vs-serve race test leans on).
pub struct Scrubber {
    stop: Arc<AtomicBool>,
    done: Arc<(Mutex<bool>, Condvar)>,
}

impl Scrubber {
    /// Start ticking. The first tick fires immediately, then every
    /// `period`; the sleep is sliced so `stop()` never waits a full
    /// period.
    pub fn start<F>(period: Duration, mut tick: F) -> Scrubber
    where
        F: FnMut(u64) + Send + 'static,
    {
        let stop = Arc::new(AtomicBool::new(false));
        let done = Arc::new((Mutex::new(false), Condvar::new()));
        let (stop2, done2) = (stop.clone(), done.clone());
        pool::spawn(move || {
            let mut round = 0u64;
            while !stop2.load(Ordering::Acquire) {
                {
                    // S20 span (stage 1 = scheduler tick; the serve-side
                    // scrub *execution* records stage 0).
                    let mut sp = obs::Span::begin(TraceKind::ScrubPass, 1);
                    sp.note(round as f64, 0.0);
                    tick(round);
                }
                round += 1;
                let mut slept = Duration::ZERO;
                while slept < period && !stop2.load(Ordering::Acquire) {
                    let slice = (period - slept).min(Duration::from_millis(1));
                    std::thread::sleep(slice);
                    slept += slice;
                }
            }
            let (lock, cv) = &*done2;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        });
        Scrubber { stop, done }
    }

    /// Map a simulated scrub interval onto a wall-clock tick period:
    /// `interval_ns(ret) / compression` nanoseconds of wall time,
    /// floored at 1 µs so a stress corner cannot busy-spin the pool.
    pub fn period_for(
        policy: &ScrubPolicy,
        ret: &RetentionParams,
        compression: f64,
    ) -> Duration {
        assert!(compression > 0.0);
        let wall_ns = (policy.interval_ns(ret) / compression).max(1_000.0);
        Duration::from_nanos(wall_ns.min(u64::MAX as f64) as u64)
    }

    /// Signal the loop to exit and block until it has (quiesce). Any
    /// tick already running completes first.
    pub fn stop(self) {
        self.stop.store(true, Ordering::Release);
        let (lock, cv) = &*self.done;
        let mut finished = lock.lock().unwrap();
        while !*finished {
            finished = cv.wait(finished).unwrap();
        }
    }
}

/// Mission clock (DESIGN.md S22): the virtual-uptime source behind
/// `serve --uptime-factor`. Wall time is compressed — every `period` of
/// wall clock the mission advances by a *fixed* `sim_dt_ns` of
/// simulated uptime and `tick(round, sim_dt_ns)` fires (typically
/// broadcasting `Drift` jobs into the stream server's FIFOs, so days of
/// operation happen with zero explicit `drift()` calls).
///
/// Unlike [`Scrubber`], the clock carries an explicit `horizon`: after
/// exactly `horizon` ticks it stops itself, making the total simulated
/// uptime `horizon × sim_dt_ns` — a deterministic quantity independent
/// of wall-clock jitter, which is what lets the EX6 arms end at
/// bit-comparable mission states. `horizon = 0` runs until
/// [`stop`](MissionClock::stop).
pub struct MissionClock {
    stop: Arc<AtomicBool>,
    done: Arc<(Mutex<bool>, Condvar)>,
    ticks: Arc<AtomicU64>,
    /// Fixed simulated uptime per tick (ns).
    pub sim_dt_ns: f64,
}

impl MissionClock {
    /// Start the mission. The first tick fires immediately; the sleep
    /// is sliced so `stop()` never waits a full period.
    pub fn start<F>(
        period: Duration,
        sim_dt_ns: f64,
        horizon: u64,
        mut tick: F,
    ) -> MissionClock
    where
        F: FnMut(u64, f64) + Send + 'static,
    {
        assert!(sim_dt_ns > 0.0, "a mission must advance simulated time");
        let stop = Arc::new(AtomicBool::new(false));
        let done = Arc::new((Mutex::new(false), Condvar::new()));
        let ticks = Arc::new(AtomicU64::new(0));
        let (stop2, done2, ticks2) = (stop.clone(), done.clone(), ticks.clone());
        pool::spawn(move || {
            let mut round = 0u64;
            while !stop2.load(Ordering::Acquire) {
                tick(round, sim_dt_ns);
                round += 1;
                ticks2.store(round, Ordering::Release);
                if horizon > 0 && round >= horizon {
                    break;
                }
                let mut slept = Duration::ZERO;
                while slept < period && !stop2.load(Ordering::Acquire) {
                    let slice = (period - slept).min(Duration::from_millis(1));
                    std::thread::sleep(slice);
                    slept += slice;
                }
            }
            let (lock, cv) = &*done2;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        });
        MissionClock {
            stop,
            done,
            ticks,
            sim_dt_ns,
        }
    }

    /// Ticks completed so far.
    pub fn ticks(&self) -> u64 {
        self.ticks.load(Ordering::Acquire)
    }

    /// Simulated uptime elapsed so far (ns).
    pub fn sim_elapsed_ns(&self) -> f64 {
        self.ticks() as f64 * self.sim_dt_ns
    }

    /// Block until the mission reaches its horizon (or is stopped).
    pub fn wait_done(&self) {
        let (lock, cv) = &*self.done;
        let mut finished = lock.lock().unwrap();
        while !*finished {
            finished = cv.wait(finished).unwrap();
        }
    }

    /// Signal the loop to exit and block until it has (quiesce). A
    /// mission that already reached its horizon returns immediately.
    pub fn stop(self) {
        self.stop.store(true, Ordering::Release);
        self.wait_done();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn standard_devices_scrub_is_free() {
        // Δ=60: scrub interval is astronomically long → zero tax.
        let pol = ScrubPolicy::standard();
        let ret = RetentionParams::standard();
        assert!(pol.duty_cycle(&ret) < 1e-12);
        assert!(pol.average_power_uw(&ret) < 1e-9);
    }

    #[test]
    fn weak_devices_pay_a_measurable_but_small_tax() {
        let pol = ScrubPolicy::standard();
        let ret = RetentionParams::weak(); // Δ=35, τ≈18 days
        let interval = pol.interval_ns(&ret);
        // p_target 1e-9 → interval ≈ τ·1e-9 ≈ 1.6e6 ns ≈ 1.6 ms.
        assert!(interval > 1e5 && interval < 1e8, "{interval}");
        let duty = pol.duty_cycle(&ret);
        assert!(duty > 0.0 && duty < 0.1, "duty {duty}");
        // Busy macro (50 % utilization at ~90 ns/MVM, 134 pJ each):
        let tax = pol.efficiency_tax(&ret, 5.0e6, 134_500.0);
        assert!(tax < 0.05, "tax {tax}"); // < 5 % energy overhead
    }

    #[test]
    fn tighter_targets_scrub_more_often() {
        let ret = RetentionParams::weak();
        let loose = ScrubPolicy {
            p_target: 1e-6,
            ..ScrubPolicy::standard()
        };
        let tight = ScrubPolicy {
            p_target: 1e-12,
            ..ScrubPolicy::standard()
        };
        assert!(tight.interval_ns(&ret) < loose.interval_ns(&ret));
        assert!(tight.duty_cycle(&ret) > loose.duty_cycle(&ret));
    }

    #[test]
    fn queue_depth_gate_skips_only_above_threshold() {
        let pol = ScrubPolicy::standard();
        assert!(!pol.should_skip(0));
        assert!(!pol.should_skip(pol.queue_depth_threshold));
        assert!(pol.should_skip(pol.queue_depth_threshold + 1));
        let eager = ScrubPolicy {
            queue_depth_threshold: 0,
            ..ScrubPolicy::standard()
        };
        assert!(!eager.should_skip(0));
        assert!(eager.should_skip(1));
    }

    #[test]
    fn idle_macro_tax_is_infinite() {
        let pol = ScrubPolicy::standard();
        let ret = RetentionParams::weak();
        assert!(pol.efficiency_tax(&ret, 0.0, 134_500.0).is_infinite());
    }

    #[test]
    fn scrubber_ticks_then_quiesces() {
        let count = Arc::new(AtomicU64::new(0));
        let c = count.clone();
        let s = Scrubber::start(Duration::from_millis(2), move |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        // The first tick fires immediately; wait until it lands.
        while count.load(Ordering::SeqCst) == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        s.stop();
        let after = count.load(Ordering::SeqCst);
        assert!(after >= 1);
        // Quiesce means quiesce: no tick fires after stop() returns.
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(count.load(Ordering::SeqCst), after);
    }

    #[test]
    fn scrubber_rounds_are_sequential() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s2 = seen.clone();
        let s = Scrubber::start(Duration::from_millis(1), move |round| {
            s2.lock().unwrap().push(round);
        });
        while seen.lock().unwrap().len() < 3 {
            std::thread::sleep(Duration::from_millis(1));
        }
        s.stop();
        let rounds = seen.lock().unwrap().clone();
        assert_eq!(rounds[..3], [0, 1, 2]);
    }

    #[test]
    fn endurance_policy_stretches_then_degrades() {
        let pol = EndurancePolicy {
            endurance: EnduranceParams { rated_cycles: 1_000 },
            throttle_start: 0.5,
            wear_ceiling: 0.9,
            max_stretch: 8.0,
        };
        // Below the throttle knee: nominal schedule, every round.
        assert_eq!(pol.stretch(0.0), 1.0);
        assert_eq!(pol.stretch(0.5), 1.0);
        assert!((0..8).all(|r| pol.scrub_this_round(0.3, r)));
        // Linear ramp: midway between knee and ceiling → midway stretch.
        let mid = pol.stretch(0.7);
        assert!((mid - 4.5).abs() < 1e-9, "stretch {mid}");
        // Stretch is monotone in wear.
        let mut prev = 0.0;
        for w in [0.0, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
            let s = pol.stretch(w);
            assert!(s >= prev && s <= pol.max_stretch);
            prev = s;
        }
        // Throttled: the round gate fires exactly on multiples of the
        // rounded stretch (comparison derived, not hard-coded, so an
        // f64 ulp in the ramp cannot flap the test).
        let s = pol.stretch(0.7).round().max(1.0) as u64;
        assert!(s >= 4, "wear 0.7 must stretch the interval, got {s}");
        let fired: Vec<u64> =
            (0..20).filter(|&r| pol.scrub_this_round(0.7, r)).collect();
        let want: Vec<u64> = (0..20).filter(|r| r % s == 0).collect();
        assert_eq!(fired, want);
        // Ceiling: degrade, never scrub.
        assert!(pol.should_degrade(0.9));
        assert!(!pol.should_degrade(0.89));
        assert!((0..20).all(|r| !pol.scrub_this_round(0.95, r)));
        // Wear plumbs through the endurance params (saturating).
        assert_eq!(pol.wear(500), 0.5);
        assert_eq!(pol.wear(2_000), 1.0);
    }

    #[test]
    fn mission_clock_honors_its_horizon_exactly() {
        let count = Arc::new(AtomicU64::new(0));
        let sim = Arc::new(Mutex::new(0.0f64));
        let (c, s) = (count.clone(), sim.clone());
        let clock =
            MissionClock::start(Duration::from_millis(1), 2.5e9, 5, move |_, dt| {
                c.fetch_add(1, Ordering::SeqCst);
                *s.lock().unwrap() += dt;
            });
        clock.wait_done();
        // Exactly horizon ticks, exactly horizon × dt simulated uptime —
        // wall jitter cannot change either.
        assert_eq!(count.load(Ordering::SeqCst), 5);
        assert_eq!(clock.ticks(), 5);
        assert_eq!(clock.sim_elapsed_ns(), 5.0 * 2.5e9);
        assert_eq!(*sim.lock().unwrap(), 5.0 * 2.5e9);
        // Stopping a finished mission returns immediately.
        clock.stop();
    }

    #[test]
    fn unbounded_mission_clock_stops_on_demand() {
        let count = Arc::new(AtomicU64::new(0));
        let c = count.clone();
        let clock =
            MissionClock::start(Duration::from_millis(2), 1e9, 0, move |_, _| {
                c.fetch_add(1, Ordering::SeqCst);
            });
        while count.load(Ordering::SeqCst) == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        clock.stop();
        let after = count.load(Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(count.load(Ordering::SeqCst), after, "quiesced");
    }

    #[test]
    fn wall_period_mapping_is_compressed_and_floored() {
        let pol = ScrubPolicy::standard();
        let weak = RetentionParams::weak();
        // τ·1e-9 ≈ 1.6e6 ns interval / 1e3 compression ≈ 1.6 µs wall.
        let p = Scrubber::period_for(&pol, &weak, 1e3);
        assert!(p >= Duration::from_micros(1));
        assert!(p < Duration::from_millis(10));
        // Absurd compression still respects the 1 µs floor.
        let q = Scrubber::period_for(&pol, &weak, 1e30);
        assert_eq!(q, Duration::from_micros(1));
    }
}

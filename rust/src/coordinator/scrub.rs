//! Retention scrub scheduler (DESIGN.md S11 × retention extension): for
//! weight-stationary deployments the coordinator must periodically
//! re-verify/refresh the programmed codes before Néel relaxation corrupts
//! them. This module computes the scrub schedule from the device's
//! retention parameters and accounts the resulting energy/availability
//! tax against the macro's budget.

use crate::device::retention::RetentionParams;

/// Scrub policy for one macro.
#[derive(Debug, Clone, Copy)]
pub struct ScrubPolicy {
    /// Target per-junction flip probability between scrubs.
    pub p_target: f64,
    /// Time to scrub one full tile (read-verify-rewrite, ns).
    pub scrub_duration_ns: f64,
    /// Energy per full-tile scrub (fJ).
    pub scrub_energy_fj: f64,
}

impl ScrubPolicy {
    /// Defaults: verify+selective-rewrite of a 128×128 tile. Reads are
    /// nearly free; energy is dominated by the expected rewrites.
    pub fn standard() -> Self {
        ScrubPolicy {
            p_target: 1e-9,
            scrub_duration_ns: 100_000.0, // 0.1 ms per tile
            scrub_energy_fj: 2.0e6,       // ~2 µJ: sparse rewrites
        }
    }

    /// Scrub interval for the given device corner (ns).
    pub fn interval_ns(&self, ret: &RetentionParams) -> f64 {
        ret.scrub_interval_ns(self.p_target)
    }

    /// Fraction of wall time spent scrubbing (availability tax).
    pub fn duty_cycle(&self, ret: &RetentionParams) -> f64 {
        self.scrub_duration_ns
            / (self.scrub_duration_ns + self.interval_ns(ret))
    }

    /// Average scrub power (µW = fJ/ns) amortized over the interval.
    pub fn average_power_uw(&self, ret: &RetentionParams) -> f64 {
        self.scrub_energy_fj / self.interval_ns(ret)
    }

    /// Relative efficiency loss when the macro runs `mvm_rate_per_s`
    /// MVMs/s at `e_mvm_fj` each: scrub energy / compute energy.
    pub fn efficiency_tax(
        &self,
        ret: &RetentionParams,
        mvm_rate_per_s: f64,
        e_mvm_fj: f64,
    ) -> f64 {
        let compute_uw = e_mvm_fj * mvm_rate_per_s * 1e-9; // fJ/s → µW
        if compute_uw <= 0.0 {
            return f64::INFINITY;
        }
        self.average_power_uw(ret) / compute_uw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_devices_scrub_is_free() {
        // Δ=60: scrub interval is astronomically long → zero tax.
        let pol = ScrubPolicy::standard();
        let ret = RetentionParams::standard();
        assert!(pol.duty_cycle(&ret) < 1e-12);
        assert!(pol.average_power_uw(&ret) < 1e-9);
    }

    #[test]
    fn weak_devices_pay_a_measurable_but_small_tax() {
        let pol = ScrubPolicy::standard();
        let ret = RetentionParams::weak(); // Δ=35, τ≈18 days
        let interval = pol.interval_ns(&ret);
        // p_target 1e-9 → interval ≈ τ·1e-9 ≈ 1.6e6 ns ≈ 1.6 ms.
        assert!(interval > 1e5 && interval < 1e8, "{interval}");
        let duty = pol.duty_cycle(&ret);
        assert!(duty > 0.0 && duty < 0.1, "duty {duty}");
        // Busy macro (50 % utilization at ~90 ns/MVM, 134 pJ each):
        let tax = pol.efficiency_tax(&ret, 5.0e6, 134_500.0);
        assert!(tax < 0.05, "tax {tax}"); // < 5 % energy overhead
    }

    #[test]
    fn tighter_targets_scrub_more_often() {
        let ret = RetentionParams::weak();
        let loose = ScrubPolicy {
            p_target: 1e-6,
            ..ScrubPolicy::standard()
        };
        let tight = ScrubPolicy {
            p_target: 1e-12,
            ..ScrubPolicy::standard()
        };
        assert!(tight.interval_ns(&ret) < loose.interval_ns(&ret));
        assert!(tight.duty_cycle(&ret) > loose.duty_cycle(&ret));
    }

    #[test]
    fn idle_macro_tax_is_infinite() {
        let pol = ScrubPolicy::standard();
        let ret = RetentionParams::weak();
        assert!(pol.efficiency_tax(&ret, 0.0, 134_500.0).is_infinite());
    }
}

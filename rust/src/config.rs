//! Macro configuration: Table I parameters plus the circuit sizing derived
//! in DESIGN.md §6. One `MacroConfig` value fully determines the behavioral
//! simulation (geometry, voltages, capacitors, coding, non-idealities).
//!
//! Unit conventions used across the whole crate (chosen so the Euler/event
//! updates need no conversion factors):
//!   time ns · voltage V · current µA · conductance µS · capacitance fF ·
//!   resistance MΩ · energy fJ  (µA·ns = fC, fC·V = fJ, µS·V = µA,
//!   µA·ns/fF = V).

/// Mapping from 2-bit weight codes to cell conductance levels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LevelMap {
    /// Levels that the series 3T-2MTJ stack physically provides:
    /// R ∈ {6,5,4,3} MΩ → G ∈ {1/6, 1/5, 1/4, 1/3} µS (code-ascending).
    DeviceTrue,
    /// Idealized equally-spaced levels over the same span (ablation).
    IdealLinear,
}

impl LevelMap {
    /// The four conductance levels in µS, indexed by code 0..=3.
    pub fn levels(self) -> [f64; 4] {
        match self {
            LevelMap::DeviceTrue => {
                [1.0 / 6.0, 1.0 / 5.0, 1.0 / 4.0, 1.0 / 3.0]
            }
            LevelMap::IdealLinear => {
                let lo = 1.0 / 6.0;
                let hi = 1.0 / 3.0;
                let step = (hi - lo) / 3.0;
                [lo, lo + step, lo + 2.0 * step, hi]
            }
        }
    }

    /// Mid-point conductance used as the signed-weight offset (DESIGN §7).
    pub fn g_mid(self) -> f64 {
        let l = self.levels();
        (l[0] + l[1] + l[2] + l[3]) / 4.0
    }
}

/// Which linear fast-path engine `CimMacro` uses for the charge
/// integral (DESIGN.md S17). The request applies only when the ideal
/// linear fast path is valid (clamp+current-mirror, no c2c noise, no
/// mirror-gain mismatch) — any non-ideality hands the op to the general
/// event loop regardless, because only the event loop models it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MvmEngine {
    /// Pick per batch: the quantized level-plane engine when it is
    /// exact (ideal circuits *and* exact level conductances *and*
    /// 16-bit count headroom), otherwise event-list vs dense streaming
    /// by batch occupancy.
    #[default]
    Auto,
    /// Row-outer weight-stationary batch streaming (DESIGN.md S16) —
    /// the PR-3 reference engine.
    Dense,
    /// Item-outer streaming over per-item active-row event lists —
    /// bit-identical to `Dense` (skipping a zero window adds exactly
    /// `+0.0`), it just never visits silent rows.
    EventList,
    /// Integer level-plane accumulation: per-(level, column) spike
    /// counts, one deterministic f64 scale per level. Exactly equal to
    /// the integer oracle (`CimMacro::ideal_mvm_quantized`); panics if
    /// forced while ineligible.
    Quantized,
}

/// Analog non-idealities applied by the behavioral circuit engine.
#[derive(Debug, Clone, Copy)]
pub struct NonIdeality {
    /// Device-to-device MTJ resistance sigma (fraction of nominal R).
    pub sigma_r_d2d: f64,
    /// Cycle-to-cycle read-conductance sigma (fraction).
    pub sigma_r_c2c: f64,
    /// Comparator input-referred offset (V, 1-sigma).
    pub comparator_offset_v: f64,
    /// Comparator propagation delay (ns).
    pub comparator_delay_ns: f64,
    /// Current-mirror gain error (fraction, 1-sigma per column).
    pub mirror_gain_sigma: f64,
    /// If false, model the Fig 7b baseline: C_rt charged directly from the
    /// bit line (RC droop) instead of through the clamp+current mirror.
    pub clamp_current_mirror: bool,
}

impl NonIdeality {
    /// Ideal circuits (bit-true temporal MAC) — the default for tests.
    pub fn ideal() -> Self {
        NonIdeality {
            sigma_r_d2d: 0.0,
            sigma_r_c2c: 0.0,
            comparator_offset_v: 0.0,
            comparator_delay_ns: 0.0,
            mirror_gain_sigma: 0.0,
            clamp_current_mirror: true,
        }
    }

    /// Realistic 28 nm-ish defaults used for robustness experiments.
    pub fn realistic() -> Self {
        NonIdeality {
            sigma_r_d2d: 0.02,
            sigma_r_c2c: 0.005,
            comparator_offset_v: 0.002,
            comparator_delay_ns: 0.05,
            mirror_gain_sigma: 0.005,
            clamp_current_mirror: true,
        }
    }
}

/// Full macro configuration (Table I + DESIGN.md §6).
#[derive(Debug, Clone)]
pub struct MacroConfig {
    /// Array rows (wordlines), paper: 128.
    pub rows: usize,
    /// Array columns (bitlines), paper: 128.
    pub cols: usize,
    /// Supply voltage (V), Table I: 1.1 V.
    pub vdd: f64,
    /// Bit-line clamp voltage (V), §IV: 400 mV.
    pub v_clamp: f64,
    /// Input clamp voltage (V), §IV: 300 mV.
    pub v_in_clamp: f64,
    /// Spike-interval LSB (ns), §IV: 0.2 ns per input bit.
    pub t_bit_ns: f64,
    /// Result capacitor (fF), §IV: 200 fF.
    pub c_rt_ff: f64,
    /// Comparison capacitor (fF), §IV: 200 fF.
    pub c_com_ff: f64,
    /// Reference charging current (µA); sized so max V_charge < VDD.
    pub i_com_ua: f64,
    /// Current-mirror gain k.
    pub k_mirror: f64,
    /// MTJ low-resistance state (MΩ), Table I: 1 MΩ.
    pub r_lrs_mohm: f64,
    /// Tunnel magnetoresistance ratio, Table I: 100 % → 1.0.
    pub tmr: f64,
    /// Input precision (bits), evaluation: 8.
    pub input_bits: u32,
    /// Weight precision (bits per cell), 3T-2MTJ: 2.
    pub weight_bits: u32,
    /// Code → conductance mapping.
    pub level_map: LevelMap,
    /// Analog non-idealities.
    pub nonideal: NonIdeality,
    /// Fast-path engine request (DESIGN.md S17).
    pub engine: MvmEngine,
}

impl Default for MacroConfig {
    fn default() -> Self {
        MacroConfig {
            rows: 128,
            cols: 128,
            vdd: 1.1,
            v_clamp: 0.400,
            v_in_clamp: 0.300,
            t_bit_ns: 0.2,
            c_rt_ff: 200.0,
            c_com_ff: 200.0,
            i_com_ua: 2.0,
            k_mirror: 1.0,
            r_lrs_mohm: 1.0,
            tmr: 1.0,
            input_bits: 8,
            weight_bits: 2,
            level_map: LevelMap::DeviceTrue,
            nonideal: NonIdeality::ideal(),
            engine: MvmEngine::Auto,
        }
    }
}

impl MacroConfig {
    /// Effective read voltage V_read = V_clamp − V_in,clamp (§III-B).
    pub fn v_read(&self) -> f64 {
        self.v_clamp - self.v_in_clamp
    }

    /// OSG sensing gain α = k·V_read·C_com / (C_rt·I_com)  [ns per µS·ns]
    /// — Eq. (2) in its dimensionally consistent form (DESIGN.md §1).
    pub fn alpha(&self) -> f64 {
        self.k_mirror * self.v_read() * self.c_com_ff
            / (self.c_rt_ff * self.i_com_ua)
    }

    /// Max input spike interval (ns): (2^bits − 1)·T_bit. 8-bit → 51 ns.
    pub fn t_in_max_ns(&self) -> f64 {
        ((1u64 << self.input_bits) - 1) as f64 * self.t_bit_ns
    }

    /// Worst-case V_charge (V): all rows at max interval & max conductance.
    /// Must stay below VDD for the OSG to be linear — checked in tests.
    pub fn v_charge_max(&self) -> f64 {
        let g_max = self.level_map.levels()[3];
        self.k_mirror * self.v_read() * g_max * self.t_in_max_ns()
            * self.rows as f64
            / self.c_rt_ff
    }

    /// Worst-case output spike interval (ns): T_out at V_charge_max.
    pub fn t_out_max_ns(&self) -> f64 {
        self.v_charge_max() * self.c_com_ff / self.i_com_ua
    }

    /// Ops per full-array MVM (1 MAC = 2 OPs, the convention of Table II).
    pub fn ops_per_mvm(&self) -> u64 {
        2 * self.rows as u64 * self.cols as u64
    }

    /// Number of distinct conductance states per cell.
    pub fn states_per_cell(&self) -> usize {
        1 << self.weight_bits
    }
}

/// Temporal streaming SNN runtime knobs (DESIGN.md S18): how static or
/// DVS-style inputs unroll into timesteps and how the per-stage LIF
/// membranes behave. One value fully determines a `stream::SpikingMlp`
/// deployment given the quantized weights.
#[derive(Debug, Clone, Copy)]
pub struct StreamConfig {
    /// Timesteps per inference (T) for static-input re-encoding.
    pub t_steps: usize,
    /// Per-step membrane decay fraction in `[0, 1)`: `v ← v·(1−leak)`
    /// before integration. 0 (default) is exact integrate-and-fire —
    /// the lossless limit of rate-coded conversion; small values model
    /// a leaky membrane.
    pub leak: f64,
    /// Calibration percentile for the per-layer normalization
    /// thresholds λ_l (same convention as `snn::quant::ActQuant`).
    pub theta_pct: f64,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            t_steps: 8,
            leak: 0.0,
            theta_pct: 99.5,
        }
    }
}

/// Tracing & telemetry knobs (DESIGN.md S20) for the `obs` recorder.
/// `kinds` is a bitmask over `obs::TraceKind` (bit = discriminant);
/// the default is **off**: every instrumented site then pays exactly
/// one relaxed atomic load (the overhead contract asserted by
/// `benches/obs.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceConfig {
    /// Per-thread ring capacity in events; a full ring drops its
    /// oldest event (counted), never blocks the recording thread.
    pub capacity: usize,
    /// Enabled `obs::TraceKind` bitmask; 0 disables all recording.
    pub kinds: u32,
}

impl TraceConfig {
    /// Tracing disabled (the default).
    pub fn off() -> TraceConfig {
        TraceConfig {
            capacity: 65_536,
            kinds: 0,
        }
    }

    /// Every span and counter kind enabled.
    pub fn all() -> TraceConfig {
        TraceConfig {
            capacity: 65_536,
            kinds: u32::MAX,
        }
    }

    /// Is any kind enabled at a nonzero capacity?
    pub fn enabled(&self) -> bool {
        self.kinds != 0 && self.capacity > 0
    }
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig::off()
    }
}

/// Chip-level fabric configuration (DESIGN.md S15): a mesh of macro
/// tiles joined by an event-driven X-Y NoC carrying spike packets.
///
/// The cost model is deliberately first-order — per-hop store-and-forward
/// latency and per-flit-per-hop link+router energy, congestion-free — the
/// same altitude as the rest of the behavioral stack. All knobs live here
/// so the `repro fabric` sweep and the serving backend share one source
/// of truth.
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// Mesh width (tiles along X).
    pub grid_x: usize,
    /// Mesh height (tiles along Y).
    pub grid_y: usize,
    /// Per-hop router+link traversal latency (ns), store-and-forward.
    pub hop_latency_ns: f64,
    /// Link+router energy per flit per hop (fJ). 100 fJ per 64-bit flit
    /// ≈ 1.6 fJ/bit/hop — an optimized 28 nm mesh (DESIGN.md S15).
    pub hop_energy_fj: f64,
    /// Flit width (bits).
    pub flit_bits: u32,
    /// Packet header (routing + layer/shard tag, bits).
    pub header_bits: u32,
    /// Bits per input value on the wire (dual-spike interval code).
    pub in_value_bits: u32,
    /// Bits per partial-result value on the wire (output interval code).
    pub out_value_bits: u32,
    /// Chip I/O port tile (x, y): inputs enter and results leave here.
    pub io_tile: (usize, usize),
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            grid_x: 8,
            grid_y: 8,
            hop_latency_ns: 1.0,
            hop_energy_fj: 100.0,
            flit_bits: 64,
            header_bits: 32,
            in_value_bits: 8,
            out_value_bits: 16,
            io_tile: (0, 0),
        }
    }
}

impl FabricConfig {
    /// Total tile slots in the mesh.
    pub fn tiles(&self) -> usize {
        self.grid_x * self.grid_y
    }

    /// Square g×g mesh with the default cost model.
    pub fn square(g: usize) -> Self {
        FabricConfig {
            grid_x: g,
            grid_y: g,
            ..FabricConfig::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults() {
        let c = MacroConfig::default();
        assert_eq!(c.rows, 128);
        assert_eq!(c.cols, 128);
        assert!((c.vdd - 1.1).abs() < 1e-12);
        assert!((c.r_lrs_mohm - 1.0).abs() < 1e-12);
        assert!((c.tmr - 1.0).abs() < 1e-12);
        assert!((c.v_read() - 0.1).abs() < 1e-12);
        assert!((c.t_bit_ns - 0.2).abs() < 1e-12);
    }

    #[test]
    fn alpha_matches_python_model() {
        // python/compile/model.py: ALPHA = 1*0.1*200/(200*2) = 0.05
        let c = MacroConfig::default();
        assert!((c.alpha() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn device_true_levels_from_series_stack() {
        let l = LevelMap::DeviceTrue.levels();
        // R = {3,4,5,6} MΩ descending code order → G ascending.
        assert!((l[0] - 1.0 / 6.0).abs() < 1e-12);
        assert!((l[3] - 1.0 / 3.0).abs() < 1e-12);
        assert!(l.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn ideal_levels_equally_spaced() {
        let l = LevelMap::IdealLinear.levels();
        let d1 = l[1] - l[0];
        let d2 = l[2] - l[1];
        let d3 = l[3] - l[2];
        assert!((d1 - d2).abs() < 1e-12 && (d2 - d3).abs() < 1e-12);
        assert!((l[0] - 1.0 / 6.0).abs() < 1e-12);
        assert!((l[3] - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn worst_case_v_charge_below_vdd() {
        let c = MacroConfig::default();
        // DESIGN §6 sizing: ~1.088 V < 1.1 V supply.
        assert!(c.v_charge_max() < c.vdd, "{}", c.v_charge_max());
        assert!(c.v_charge_max() > 0.9 * c.vdd); // tight sizing, not lazy
    }

    #[test]
    fn t_in_max_is_51ns_at_8bit() {
        let c = MacroConfig::default();
        assert!((c.t_in_max_ns() - 51.0).abs() < 1e-9);
    }

    #[test]
    fn ops_per_mvm_is_32768() {
        assert_eq!(MacroConfig::default().ops_per_mvm(), 32768);
    }

    #[test]
    fn g_mid_is_level_mean() {
        let lm = LevelMap::DeviceTrue;
        let l = lm.levels();
        assert!((lm.g_mid() - l.iter().sum::<f64>() / 4.0).abs() < 1e-15);
    }

    #[test]
    fn stream_defaults_are_sane() {
        let s = StreamConfig::default();
        assert!(s.t_steps >= 1);
        assert!((0.0..1.0).contains(&s.leak));
        assert!(s.theta_pct > 90.0 && s.theta_pct <= 100.0);
    }

    #[test]
    fn fabric_defaults_are_consistent() {
        let f = FabricConfig::default();
        assert_eq!(f.tiles(), 64);
        assert!(f.io_tile.0 < f.grid_x && f.io_tile.1 < f.grid_y);
        assert!(f.hop_latency_ns > 0.0 && f.hop_energy_fj > 0.0);
        let s = FabricConfig::square(2);
        assert_eq!((s.grid_x, s.grid_y, s.tiles()), (2, 2, 4));
    }
}

//! Concurrent observability + end-to-end trace export (DESIGN.md S20).
//!
//! Two acceptance bars:
//!
//! * **Concurrency**: N threads hammering `record_request` /
//!   `record_activity` / spans while another thread continuously drains
//!   snapshots and trace exports — the counter totals must equal the
//!   sum of per-thread contributions, and the exporter must never
//!   deadlock with the worker pool (drain takes registry → ring;
//!   writers only ever take their own ring).
//! * **End-to-end**: a short stream-server workload with every kind
//!   enabled yields a Perfetto `trace_event` JSON containing spans from
//!   ≥ 4 distinct stages (pool job, macro MVM, NoC route, stream stage)
//!   plus counter events, validated by a `util::json::parse` round
//!   trip of the exact bytes written.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use spikemram::config::{
    FabricConfig, LevelMap, MacroConfig, StreamConfig, TraceConfig,
};
use spikemram::coordinator::Metrics;
use spikemram::obs::{self, TraceKind};
use spikemram::snn::{Dataset, Mlp};
use spikemram::stream::{
    FrameEncoder, StreamServer, StreamServerConfig, StreamSpec, TemporalCode,
};
use spikemram::util::json::{self, Json};
use spikemram::util::pool;

/// obs state (kind mask, rings) is process-global; serialize the tests
/// that install/drain it.
fn lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[test]
fn concurrent_hammer_preserves_totals_and_never_deadlocks() {
    let _g = lock();
    obs::install(&TraceConfig::all());
    let metrics = Arc::new(Metrics::new());
    let stop = Arc::new(AtomicBool::new(false));
    const THREADS: usize = 4;
    const ITERS: usize = 2_000;
    std::thread::scope(|s| {
        // Drainer: snapshots, ring drains, and chrome serialization in
        // a tight loop, concurrent with every writer.
        {
            let m = Arc::clone(&metrics);
            let stop = Arc::clone(&stop);
            s.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let _ = m.snapshot().to_json().to_string();
                    let report = obs::drain();
                    let _ = obs::chrome_trace(&report).to_string();
                    m.absorb_trace(&report);
                }
            });
        }
        // Pool churn: keeps scope tickets (and their spans) flowing
        // through the shared worker pool under the drains — the
        // deadlock-freedom half of the bar.
        {
            let stop = Arc::clone(&stop);
            s.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let v = pool::scope_map(
                        (0..32usize).collect::<Vec<_>>(),
                        |i| i * 2,
                    );
                    assert_eq!(v[31], 62);
                }
            });
        }
        let mut writers = Vec::new();
        for t in 0..THREADS {
            let m = Arc::clone(&metrics);
            writers.push(s.spawn(move || {
                for i in 0..ITERS {
                    m.record_request(10.0 + (i % 7) as f64);
                    m.record_batch(1, 100);
                    m.record_activity(8, 16);
                    let mut sp =
                        obs::Span::begin(TraceKind::MacroMvm, t as u16);
                    sp.note(i as f64, 1.0);
                }
            }));
        }
        for w in writers {
            w.join().expect("writer");
        }
        stop.store(true, Ordering::Release);
    });
    obs::install(&TraceConfig::off());
    let n = (THREADS * ITERS) as u64;
    let snap = metrics.snapshot();
    assert_eq!(snap.requests, n, "every record_request landed");
    assert_eq!(snap.batches, n);
    assert_eq!(snap.macs, n * 100);
    assert_eq!(snap.active_rows, n * 8);
    assert_eq!(snap.row_slots, n * 16);
    // Whatever the drainer didn't absorb is still in the rings.
    metrics.absorb_trace(&obs::drain());
}

#[test]
fn stream_trace_exports_perfetto_json_with_all_stage_kinds() {
    let _g = lock();
    obs::install(&TraceConfig::all());
    let spec = StreamSpec {
        model: Mlp::new(5),
        calib: Dataset::generate(32, 5),
        mcfg: MacroConfig::default(),
        fabric: FabricConfig::square(2),
        level_map: LevelMap::DeviceTrue,
        stream: StreamConfig::default(),
    };
    let server = StreamServer::start(
        spec,
        StreamServerConfig {
            workers: 2,
            ..StreamServerConfig::default()
        },
    )
    .expect("deploy");
    let enc = FrameEncoder::new(TemporalCode::Rate, 3, 255);
    let data = Dataset::generate(4, 9);
    for i in 0..4 {
        let id = server.open_session();
        for f in enc.encode_frames(&data.features_u8(i)) {
            server.frame(id, f);
        }
        server.finish(id);
    }
    obs::install(&TraceConfig::off());
    let report = obs::drain();

    // The acceptance bar: ≥ 4 distinct span stages, counters present.
    let kinds = report.span_kinds();
    for want in [
        TraceKind::PoolExec,
        TraceKind::MacroMvm,
        TraceKind::NocRoute,
        TraceKind::StreamStage,
        TraceKind::ServeFrame,
    ] {
        assert!(kinds.contains(&want), "missing {want:?} in {kinds:?}");
    }
    assert!(kinds.len() >= 4, "{kinds:?}");
    assert!(report.has_counters(), "occupancy/energy counters expected");

    // Export and round-trip the exact bytes through the vendored
    // parser.
    let dir = std::env::temp_dir().join("spikemram_obs_trace_test");
    let path = dir.join("trace_e2e.json");
    let p = obs::write_chrome_trace(&path, &report).expect("export");
    let text = std::fs::read_to_string(&p).expect("read back");
    let back = json::parse(&text).expect("round trip");
    let evs = back
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents");
    assert!(evs.len() > report.threads.len(), "more than metadata");
    let ph = |e: &Json| e.get("ph").and_then(Json::as_str).map(str::to_string);
    assert!(evs.iter().any(|e| ph(e).as_deref() == Some("X")), "spans");
    assert!(evs.iter().any(|e| ph(e).as_deref() == Some("C")), "counters");
    assert!(evs.iter().any(|e| ph(e).as_deref() == Some("M")), "metadata");

    // Folding the report into Metrics surfaces per-span gauges.
    server.metrics.absorb_trace(&report);
    let snap = server.metrics.snapshot();
    assert!(snap.trace_events > 0);
    assert!(
        snap.spans.iter().any(|s| s.name == "macro.mvm" && s.count > 0),
        "{:?}",
        snap.spans
    );
    let _ = std::fs::remove_file(&p);
    server.shutdown();
}

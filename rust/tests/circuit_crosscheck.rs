//! Cross-checks between the two resolutions of the circuit engine:
//! the event-analytic hot path and dense RK4/Euler transients must agree
//! to discretization error, and both must match hand-derived closed forms.

use spikemram::circuit::osg::{self, OsgParams};
use spikemram::circuit::transient::{integrate, TransientConfig, TransientSystem};
use spikemram::config::MacroConfig;
use spikemram::util::rng::Rng;

fn params() -> OsgParams {
    let cfg = MacroConfig::default();
    OsgParams::ideal(cfg.v_read(), cfg.c_rt_ff, cfg.c_com_ff, cfg.i_com_ua)
}

/// The droop-mode column as a TransientSystem for RK4.
struct DroopColumn {
    windows: Vec<(f64, f64)>,
    v_read: f64,
    c_ff: f64,
}

impl TransientSystem for DroopColumn {
    fn dim(&self) -> usize {
        1
    }
    fn deriv(&self, t: f64, v: &[f64], dv: &mut [f64]) {
        let g_on: f64 = self
            .windows
            .iter()
            .filter(|&&(tf, _)| t < tf)
            .map(|&(_, g)| g)
            .sum();
        dv[0] = g_on * (self.v_read - v[0]) / self.c_ff;
    }
    fn names(&self) -> Vec<String> {
        vec!["v_charge".into()]
    }
}

#[test]
fn analytic_droop_matches_rk4_integration() {
    let mut rng = Rng::new(2001);
    for _case in 0..10 {
        let k = 1 + rng.below(64) as usize;
        let windows: Vec<(f64, f64)> = (0..k)
            .map(|_| (rng.uniform(0.5, 40.0), rng.uniform(0.1, 0.34)))
            .collect();
        let t_end = windows.iter().map(|&(t, _)| t).fold(0.0, f64::max);

        let mut p = params();
        p.clamp_cm_enabled = false;
        let analytic = osg::charge_phase(&p, &windows, t_end);

        let sys = DroopColumn {
            windows: windows.clone(),
            v_read: p.v_read,
            c_ff: p.c_rt_ff,
        };
        let (v, _) = integrate(
            &sys,
            &[0.0],
            &TransientConfig {
                dt_ns: 0.0005,
                t_end_ns: t_end,
                record_stride: 1_000_000,
            },
        );
        // RK4 smears the conductance steps over one dt; tolerance reflects
        // that, not model disagreement.
        assert!(
            (v[0] - analytic).abs() < 5e-5,
            "rk4 {} vs analytic {analytic}",
            v[0]
        );
    }
}

#[test]
fn analytic_mirror_charge_equals_closed_form_sum() {
    let mut rng = Rng::new(2002);
    let p = params();
    for _case in 0..20 {
        let k = 1 + rng.below(128) as usize;
        let windows: Vec<(f64, f64)> = (0..k)
            .map(|_| (rng.uniform(0.2, 51.0), rng.uniform(0.16, 0.34)))
            .collect();
        let t_end = windows.iter().map(|&(t, _)| t).fold(0.0, f64::max);
        let got = osg::charge_phase(&p, &windows, t_end);
        let want: f64 = windows
            .iter()
            .map(|&(t, g)| p.v_read * t * g / p.c_rt_ff)
            .sum();
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }
}

#[test]
fn droop_never_exceeds_mirror_charge() {
    let mut rng = Rng::new(2003);
    for _case in 0..20 {
        let k = 1 + rng.below(128) as usize;
        let windows: Vec<(f64, f64)> = (0..k)
            .map(|_| (rng.uniform(0.2, 51.0), rng.uniform(0.16, 0.34)))
            .collect();
        let t_end = windows.iter().map(|&(t, _)| t).fold(0.0, f64::max);
        let ideal = params();
        let mut droop = ideal;
        droop.clamp_cm_enabled = false;
        let v_i = osg::charge_phase(&ideal, &windows, t_end);
        let v_d = osg::charge_phase(&droop, &windows, t_end);
        assert!(v_d <= v_i + 1e-12, "droop {v_d} > ideal {v_i}");
        assert!(v_d >= 0.0);
        // Droop charge is also bounded by V_read (RC asymptote).
        assert!(v_d <= ideal.v_read + 1e-12);
    }
}

#[test]
fn compare_phase_inverts_charge_linearly() {
    let p = params();
    let mut rng = Rng::new(2004);
    for _ in 0..50 {
        let v = rng.uniform(0.0, 1.0);
        let t = osg::compare_phase(&p, v);
        // slope I/C = 0.01 V/ns ⇒ t = 100·v
        assert!((t - 100.0 * v).abs() < 1e-9);
    }
}

#[test]
fn full_macro_vs_manual_column_sum() {
    // The macro's event loop must agree with per-column manual evaluation.
    use spikemram::macro_model::CimMacro;
    let cfg = MacroConfig::default();
    let mut rng = Rng::new(2005);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    let mut m = CimMacro::new(cfg.clone());
    m.program(&codes);
    let x: Vec<u32> = (0..cfg.rows).map(|_| rng.below(256) as u32).collect();
    let r = m.mvm(&x);

    let levels = cfg.level_map.levels();
    let p = params();
    for c in [0usize, 17, 64, 127] {
        let windows: Vec<(f64, f64)> = (0..cfg.rows)
            .filter(|&row| x[row] > 0)
            .map(|row| {
                (
                    x[row] as f64 * cfg.t_bit_ns,
                    levels[codes[row * cfg.cols + c] as usize],
                )
            })
            .collect();
        let t_end = windows.iter().map(|&(t, _)| t).fold(0.0, f64::max);
        let col = osg::convert(&p, &windows, t_end);
        assert!(
            (col.t_out_ns - r.t_out_ns[c]).abs() < 1e-9,
            "col {c}: {} vs {}",
            col.t_out_ns,
            r.t_out_ns[c]
        );
    }
}

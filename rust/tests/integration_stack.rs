//! Integration: the three-layer stack composes.
//!
//! Loads the AOT HLO artifacts (built by `make artifacts`) into the PJRT
//! runtime and cross-checks them against the behavioral macro simulator —
//! the L1/L2 kernels and the L3 event-driven sim must implement the *same*
//! math (Eq. 2) through entirely different code paths.
//!
//! Requires `artifacts/` (run `make artifacts` first); tests are skipped
//! with a notice when it is missing so plain `cargo test` stays green.

use spikemram::config::MacroConfig;
use spikemram::macro_model::CimMacro;
use spikemram::runtime::{Manifest, Runtime, Value};
use spikemram::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("SPIKEMRAM_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: {dir}/manifest.json missing — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_contract_matches_runtime_expectations() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    for name in [
        "spiking_mvm_b8_128x128",
        "spiking_mvm_b32_128x128",
        "macro_fwd_b8",
        "mlp_fwd_b16",
        "fig7b_transient",
    ] {
        assert!(m.get(name).is_some(), "manifest missing {name}");
        let e = m.get(name).unwrap();
        assert!(
            std::path::Path::new(&dir).join(&e.file).exists(),
            "artifact file missing for {name}"
        );
    }
    // The alpha the artifacts were lowered with must equal the rust config.
    let alpha = m.get("spiking_mvm_b8_128x128").unwrap().alpha;
    assert!((alpha - MacroConfig::default().alpha()).abs() < 1e-12);
}

#[test]
fn pjrt_mvm_matches_behavioral_sim_bit_true() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = MacroConfig::default();
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("spiking_mvm_b8_128x128").unwrap();

    let mut rng = Rng::new(1001);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    let mut sim = CimMacro::new(cfg.clone());
    sim.program(&codes);

    let xs: Vec<Vec<u32>> = (0..8)
        .map(|_| (0..cfg.rows).map(|_| rng.below(256) as u32).collect())
        .collect();
    let mut t_in = vec![0.0f32; 8 * cfg.rows];
    for (b, x) in xs.iter().enumerate() {
        for (r, &v) in x.iter().enumerate() {
            t_in[b * cfg.rows + r] = v as f32 * cfg.t_bit_ns as f32;
        }
    }
    let out = exe
        .run_f32(&[
            Value::f32(t_in, &[8, cfg.rows]),
            Value::i32(
                codes.iter().map(|&c| c as i32).collect(),
                &[cfg.rows, cfg.cols],
            ),
        ])
        .unwrap();
    for (b, x) in xs.iter().enumerate() {
        let r = sim.mvm(x);
        for c in 0..cfg.cols {
            let pjrt = out[0][b * cfg.cols + c] as f64;
            let sim_t = r.t_out_ns[c];
            let rel = (pjrt - sim_t).abs() / sim_t.abs().max(1e-6);
            assert!(
                rel < 1e-5,
                "batch {b} col {c}: pjrt {pjrt} vs sim {sim_t}"
            );
        }
    }
}

#[test]
fn pjrt_macro_fwd_decodes_to_digital_macs() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = MacroConfig::default();
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("macro_fwd_b8").unwrap();
    let mut rng = Rng::new(1002);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    let x: Vec<i32> = (0..8 * cfg.rows)
        .map(|_| rng.below(256) as i32)
        .collect();
    let out = exe
        .run_f32(&[
            Value::i32(x.clone(), &[8, cfg.rows]),
            Value::i32(
                codes.iter().map(|&c| c as i32).collect(),
                &[cfg.rows, cfg.cols],
            ),
        ])
        .unwrap();
    assert_eq!(out.len(), 2, "macro_fwd returns (t_out, y)");
    // y must equal the digital oracle.
    let mut sim = CimMacro::new(cfg.clone());
    sim.program(&codes);
    for b in 0..8 {
        let xb: Vec<u32> = (0..cfg.rows)
            .map(|r| x[b * cfg.rows + r] as u32)
            .collect();
        let want = sim.ideal_mvm(&xb);
        for c in 0..cfg.cols {
            let got = out[1][b * cfg.cols + c] as f64;
            let rel = (got - want[c]).abs() / want[c].max(1.0);
            assert!(rel < 1e-4, "b{b} c{c}: {got} vs {}", want[c]);
        }
    }
}

#[test]
fn pjrt_fig7b_transient_matches_rust_circuit_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = MacroConfig::default();
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("fig7b_transient").unwrap();

    let mut rng = Rng::new(1003);
    let levels = cfg.level_map.levels();
    let t_in: Vec<f32> = (0..128)
        .map(|_| (rng.below(256) as f32) * cfg.t_bit_ns as f32)
        .collect();
    let g: Vec<f32> = (0..128)
        .map(|_| levels[rng.below(4) as usize] as f32)
        .collect();
    let out = exe
        .run_f32(&[
            Value::f32(t_in.clone(), &[128]),
            Value::f32(g.clone(), &[128]),
        ])
        .unwrap();
    assert_eq!(out.len(), 2, "(v_mirror, v_droop)");
    let n = out[0].len();
    assert_eq!(n, 1000);

    // Rust analytic engine at the same probe time (t = 5 ns, dt = 0.01).
    use spikemram::circuit::osg::{charge_phase, OsgParams};
    let windows: Vec<(f64, f64)> = t_in
        .iter()
        .zip(&g)
        .map(|(&t, &gg)| (t as f64, gg as f64))
        .collect();
    let ideal =
        OsgParams::ideal(cfg.v_read(), cfg.c_rt_ff, cfg.c_com_ff, cfg.i_com_ua);
    let mut droop = ideal;
    droop.clamp_cm_enabled = false;

    let t_probe = 5.0;
    let clipped: Vec<(f64, f64)> = windows
        .iter()
        .map(|&(t, gg)| (t.min(t_probe), gg))
        .collect();
    let v_mirror_rust = charge_phase(&ideal, &clipped, t_probe);
    let v_droop_rust = charge_phase(&droop, &clipped, t_probe);
    let idx = 499; // step 499 ends at t = 5.0 ns
    let v_mirror_pjrt = out[0][idx] as f64;
    let v_droop_pjrt = out[1][idx] as f64;
    assert!(
        (v_mirror_pjrt - v_mirror_rust).abs() < 2e-3,
        "mirror: {v_mirror_pjrt} vs {v_mirror_rust}"
    );
    assert!(
        (v_droop_pjrt - v_droop_rust).abs() < 2e-3,
        "droop: {v_droop_pjrt} vs {v_droop_rust}"
    );
    // And the droop ordering holds in both engines.
    assert!(v_droop_pjrt < v_mirror_pjrt);
}

#[test]
fn pjrt_server_backend_matches_sim_backend() {
    let Some(dir) = artifacts_dir() else { return };
    use spikemram::coordinator::{BackendKind, MacroServer, ServerConfig};
    let cfg = MacroConfig::default();
    let mut rng = Rng::new(1004);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();

    let sim = MacroServer::start(
        cfg.clone(),
        codes.clone(),
        ServerConfig {
            backend: BackendKind::Sim,
            workers: 1,
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let pjrt = MacroServer::start(
        cfg.clone(),
        codes,
        ServerConfig {
            backend: BackendKind::Pjrt { artifacts_dir: dir },
            workers: 1,
            ..ServerConfig::default()
        },
    )
    .unwrap();

    for _ in 0..4 {
        let x: Vec<u32> = (0..cfg.rows).map(|_| rng.below(256) as u32).collect();
        let a = sim.call(x.clone());
        let b = pjrt.call(x);
        for (va, vb) in a.iter().zip(&b) {
            let rel = (va - vb).abs() / va.abs().max(1.0);
            assert!(rel < 1e-4, "{va} vs {vb}");
        }
    }
    sim.shutdown();
    pjrt.shutdown();
}

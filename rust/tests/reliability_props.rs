//! Property tests for the reliability runtime's model layer
//! (DESIGN.md S19): the retention law, its scrub-interval inverse, the
//! scrub policy's derived rates, and the determinism contract of the
//! corruption sampler. These are the algebraic guarantees every higher
//! layer (fault runtime, scrubber, EX4) silently leans on.

use spikemram::coordinator::{EndurancePolicy, ScrubPolicy};
use spikemram::device::retention::{corrupt_codes, EnduranceParams};
use spikemram::device::RetentionParams;
use spikemram::util::rng::Rng;

/// Technology corners swept by every property below: from the EX4
/// stress corner up to the 10-year embedded-MRAM target.
fn corners() -> Vec<RetentionParams> {
    let mut out = vec![
        RetentionParams::stress(),
        RetentionParams::weak(),
        RetentionParams::standard(),
    ];
    for delta in [5.0, 10.0, 22.0, 48.0] {
        for tau0_ns in [0.5, 1.0, 2.0] {
            out.push(RetentionParams { delta, tau0_ns });
        }
    }
    out
}

/// Geometric time grid spanning ~15 decades around a corner's τ.
fn time_grid(tau_ns: f64) -> Vec<f64> {
    (-8..=7).map(|e| tau_ns * 10f64.powi(e)).collect()
}

#[test]
fn flip_probability_is_bounded_and_monotone_in_time() {
    for ret in corners() {
        assert_eq!(ret.flip_probability(0.0), 0.0);
        assert_eq!(ret.flip_probability(-1.0), 0.0, "no time travel");
        let mut prev = 0.0;
        for t in time_grid(ret.tau_ret_ns()) {
            let p = ret.flip_probability(t);
            assert!(
                (0.0..=0.5).contains(&p),
                "Δ={} t={t}: p={p} outside [0, ½]",
                ret.delta
            );
            assert!(
                p >= prev,
                "Δ={} t={t}: p={p} < prev {prev} (not monotone)",
                ret.delta
            );
            prev = p;
        }
        // Saturation: far past τ the two wells are equally likely.
        assert!((ret.flip_probability(1e4 * ret.tau_ret_ns()) - 0.5).abs() < 1e-12);
        assert!(ret.flip_probability(f64::MAX) <= 0.5);
    }
}

#[test]
fn scrub_interval_is_the_exact_inverse_of_flip_probability() {
    for ret in corners() {
        let mut prev_t = 0.0;
        for p_target in [1e-12, 1e-9, 1e-6, 1e-3, 0.1, 0.4, 0.499] {
            let t = ret.scrub_interval_ns(p_target);
            assert!(t > 0.0 && t.is_finite(), "Δ={}: t={t}", ret.delta);
            // Inverse-consistency: flipping for exactly the interval
            // lands back on the target.
            let p = ret.flip_probability(t);
            assert!(
                (p - p_target).abs() / p_target < 1e-6,
                "Δ={}: round-trip {p} vs {p_target}",
                ret.delta
            );
            // Looser targets buy strictly longer intervals.
            assert!(t > prev_t, "Δ={}: interval not monotone", ret.delta);
            prev_t = t;
        }
    }
}

#[test]
#[should_panic(expected = "p_target must be in (0, 0.5)")]
fn scrub_interval_rejects_unreachable_targets() {
    // ½ is the thermal-equilibrium asymptote — no finite interval
    // reaches it.
    RetentionParams::weak().scrub_interval_ns(0.5);
}

#[test]
#[should_panic(expected = "p_target must be in (0, 0.5)")]
fn scrub_interval_rejects_zero_target() {
    RetentionParams::weak().scrub_interval_ns(0.0);
}

#[test]
fn scrub_policy_rates_are_well_formed_across_a_seeded_sweep() {
    let mut rng = Rng::new(0x5eed);
    for ret in corners() {
        for _ in 0..16 {
            let pol = ScrubPolicy {
                p_target: 10f64.powf(-12.0 + 11.0 * rng.f64()),
                scrub_duration_ns: 1e3 + 1e6 * rng.f64(),
                scrub_energy_fj: 1e3 + 1e7 * rng.f64(),
                ..ScrubPolicy::standard()
            };
            let duty = pol.duty_cycle(&ret);
            assert!(
                duty > 0.0 && duty <= 1.0,
                "Δ={} duty={duty}",
                ret.delta
            );
            assert!(pol.average_power_uw(&ret) >= 0.0);
            // Efficiency tax ≥ 0 for any busy macro, ∞ for an idle one.
            let tax = pol.efficiency_tax(&ret, 1e6 * rng.f64(), 1e5);
            assert!(tax >= 0.0, "Δ={} tax={tax}", ret.delta);
            assert!(pol.efficiency_tax(&ret, 0.0, 1e5).is_infinite());
        }
    }
}

#[test]
fn corrupt_codes_is_deterministic_for_a_fixed_seed() {
    let ret = RetentionParams::stress();
    let t = ret.tau_ret_ns();
    let fresh: Vec<u8> = (0..4096).map(|i| (i % 4) as u8).collect();

    let mut a = fresh.clone();
    let mut b = fresh.clone();
    let na = corrupt_codes(&mut a, t, &ret, &mut Rng::new(777));
    let nb = corrupt_codes(&mut b, t, &ret, &mut Rng::new(777));
    assert!(na > 0, "stress corner at t=τ must corrupt");
    assert_eq!(na, nb);
    assert_eq!(a, b, "same seed → identical corruption pattern");

    // A different seed scatters differently (overwhelmingly likely at
    // ~68 % per-cell corruption over 4096 cells).
    let mut c = fresh.clone();
    corrupt_codes(&mut c, t, &ret, &mut Rng::new(778));
    assert_ne!(a, c, "independent seed → independent pattern");

    // Draw-count contract: exactly two draws per cell whenever p > 0,
    // so downstream consumers can fork RNG streams around the sampler
    // without desync.
    let mut rng = Rng::new(99);
    let mut codes = vec![0u8; 100];
    corrupt_codes(&mut codes, t, &ret, &mut rng);
    let mut reference = Rng::new(99);
    for _ in 0..200 {
        reference.f64();
    }
    assert_eq!(rng.f64(), reference.f64(), "two draws per cell, no more");
}

#[test]
fn wear_is_monotone_and_saturates_at_rated_cycles() {
    let mut rng = Rng::new(0xead_beef);
    for _ in 0..64 {
        let rated = 1 + rng.below(1_000_000_000);
        let e = EnduranceParams {
            rated_cycles: rated,
        };
        // Monotone over a grid spanning fresh → far past rated life.
        let mut prev = -1.0;
        for mult in
            [0.0, 1e-6, 1e-3, 0.1, 0.5, 0.999, 1.0, 1.5, 8.0, 1e3]
        {
            let w = e.wear((rated as f64 * mult) as u64);
            assert!(
                (0.0..=1.0).contains(&w),
                "rated={rated} mult={mult}: wear={w} outside [0, 1]"
            );
            assert!(
                w >= prev,
                "rated={rated} mult={mult}: wear not monotone"
            );
            prev = w;
        }
        // Exact endpoints: a fresh die is unworn; at the rated count
        // the budget is spent; past it the fraction saturates — it
        // never reads past 100 % no matter how long a mission runs.
        assert_eq!(e.wear(0), 0.0);
        assert_eq!(e.wear(rated), 1.0);
        assert_eq!(e.wear(rated.saturating_mul(1000)), 1.0);
        assert_eq!(e.wear(u64::MAX), 1.0);
    }
}

#[test]
fn endurance_policy_stretch_is_monotone_between_its_anchors() {
    let pol = EndurancePolicy::standard();
    let mut prev = 0.0;
    for i in 0..=1000 {
        let wear = i as f64 / 1000.0;
        let s = pol.stretch(wear);
        assert!(
            (1.0..=pol.max_stretch).contains(&s),
            "wear={wear}: stretch={s} outside [1, max]"
        );
        assert!(s >= prev, "wear={wear}: stretch not monotone");
        prev = s;
    }
    // Anchors: nominal schedule below the throttle knee, full stretch
    // at (and past) the ceiling.
    assert_eq!(pol.stretch(0.0), 1.0);
    assert_eq!(pol.stretch(pol.throttle_start), 1.0);
    assert_eq!(pol.stretch(pol.wear_ceiling), pol.max_stretch);
    assert_eq!(pol.stretch(1.0), pol.max_stretch);

    // The round gate: every round at nominal wear, never once the
    // ceiling forces the degrade path instead.
    for round in 0..32 {
        assert!(pol.scrub_this_round(0.0, round));
        assert!(!pol.scrub_this_round(pol.wear_ceiling, round));
    }
    // In the throttle band the gate fires exactly on multiples of the
    // rounded stretch — deterministic, so identical wear trajectories
    // make identical schedules.
    let wear = 0.7;
    let s = pol.stretch(wear).round().max(1.0) as u64;
    assert!(s > 1, "0.7 wear must throttle under the standard policy");
    for round in 0..64 {
        assert_eq!(pol.scrub_this_round(wear, round), round % s == 0);
    }
    assert!(!pol.should_degrade(pol.wear_ceiling - 1e-9));
    assert!(pol.should_degrade(pol.wear_ceiling));
    assert!(pol.should_degrade(1.0));
}

#[test]
fn corrupt_codes_is_a_strict_noop_at_zero_probability() {
    let fresh: Vec<u8> = (0..512).map(|i| (i % 4) as u8).collect();
    let ret = RetentionParams::standard();
    for t in [0.0, -5.0] {
        let mut codes = fresh.clone();
        let mut rng = Rng::new(5);
        assert_eq!(corrupt_codes(&mut codes, t, &ret, &mut rng), 0);
        assert_eq!(codes, fresh);
        // p = 0 consumes no randomness at all.
        assert_eq!(rng.f64(), Rng::new(5).f64());
    }
}

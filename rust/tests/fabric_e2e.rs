//! Fabric integration (DESIGN.md S15): the multi-macro fabric must be a
//! *transparent* deployment target — bit-identical math, identical
//! accuracy — while adding only modeled NoC traffic on top, and the
//! pipelined dataflow executor must match the serial fabric exactly.

use spikemram::config::{FabricConfig, LevelMap, MacroConfig, MvmEngine};
use spikemram::snn;

fn tiny_setup() -> (snn::Mlp, snn::Dataset, snn::Dataset) {
    let train = snn::Dataset::generate(150, 7001);
    let test = snn::Dataset::generate(60, 7002);
    let (model, acc) = snn::train(&train, 5, 17);
    assert!(acc > 0.85, "float train acc {acc}");
    (model, train, test)
}

#[test]
fn fabric_inference_bit_identical_to_single_macro_tiling() {
    let (model, train, test) = tiny_setup();
    let cfg = MacroConfig::default();
    let mut tiles =
        snn::MacroMlp::from_float(&model, &train, &cfg, LevelMap::DeviceTrue);
    let mut fabric =
        snn::MacroMlp::from_float(&model, &train, &cfg, LevelMap::DeviceTrue)
            .attach_fabric(&cfg, FabricConfig::square(2))
            .unwrap();
    assert!(fabric.on_fabric() && !tiles.on_fabric());

    // Batch-1 streaming: every example's logits must match bit-for-bit.
    for i in 0..test.len() {
        let x = test.features_u8(i);
        let (lt, st) = tiles.forward(&x);
        let (lf, sf) = fabric.forward(&x);
        assert_eq!(lt, lf, "logits diverge at example {i}");
        assert_eq!(st.macs, sf.macs);
        // Identical macro physics — the fabric only adds NoC energy.
        let fabric_compute = sf.energy.total_fj() - sf.energy.noc_fj;
        assert!(
            (st.energy.total_fj() - fabric_compute).abs() < 1e-6,
            "compute energy diverged at example {i}"
        );
        assert!(sf.energy.noc_fj > 0.0);
        assert!(sf.noc_hops > 0 && sf.noc_packets > 0);
        assert!(sf.latency_ns > st.latency_ns, "NoC adds latency");
    }

    let (acc_t, _) = tiles.evaluate(&test);
    let (acc_f, stats_f) = fabric.evaluate(&test);
    assert_eq!(acc_t, acc_f, "fabric must not change accuracy");
    // NoC overhead is a minority share of the end-to-end breakdown.
    let share = stats_f.energy.noc_fj / stats_f.energy.total_fj();
    assert!(share > 0.0 && share < 0.35, "NoC share {share}");
}

#[test]
fn pipelined_fabric_evaluate_matches_serial_fabric() {
    let (model, train, test) = tiny_setup();
    let cfg = MacroConfig::default();
    let build = || {
        snn::MacroMlp::from_float(&model, &train, &cfg, LevelMap::DeviceTrue)
            .attach_fabric(&cfg, FabricConfig::square(2))
            .unwrap()
    };
    let (acc_serial, st_serial) = build().evaluate(&test);
    let (acc_pipe, st_pipe) = build().evaluate_pipelined(&test);

    assert_eq!(acc_serial, acc_pipe, "pipelining must not change results");
    assert_eq!(st_serial.macs, st_pipe.macs);
    assert_eq!(st_serial.noc_packets, st_pipe.noc_packets);
    assert_eq!(st_serial.noc_hops, st_pipe.noc_hops);
    // Per-stage accumulation order differs from per-item order; totals
    // agree to float roundoff.
    let rel = (st_serial.energy.total_fj() - st_pipe.energy.total_fj())
        .abs()
        / st_serial.energy.total_fj();
    assert!(rel < 1e-9, "energy rel diff {rel}");
    let lat_rel = (st_serial.latency_ns - st_pipe.latency_ns).abs()
        / st_serial.latency_ns;
    assert!(lat_rel < 1e-9, "latency rel diff {lat_rel}");
}

#[test]
fn batched_forward_bit_identical_to_per_input_forward() {
    // DESIGN.md S16: the batched engine is a pure throughput
    // optimization — logits, energy, latency, and NoC tallies per item
    // must be bitwise what the per-input path produces, on both the
    // tile-pool and fabric deployments.
    let (model, train, test) = tiny_setup();
    let cfg = MacroConfig::default();
    let builds: [fn(&snn::Mlp, &snn::Dataset, &MacroConfig) -> snn::MacroMlp;
        2] = [
        |m, d, c| snn::MacroMlp::from_float(m, d, c, LevelMap::DeviceTrue),
        |m, d, c| {
            snn::MacroMlp::from_float(m, d, c, LevelMap::DeviceTrue)
                .attach_fabric(c, FabricConfig::square(2))
                .unwrap()
        },
    ];
    for build in builds {
        let mut serial = build(&model, &train, &cfg);
        let mut batched = build(&model, &train, &cfg);
        let xs: Vec<Vec<u32>> =
            (0..11).map(|i| test.features_u8(i)).collect();
        let want: Vec<_> = xs.iter().map(|x| serial.forward(x)).collect();
        let got = batched.forward_batch(&xs);
        assert_eq!(got.len(), want.len());
        for (i, ((gl, gs), (wl, ws))) in got.iter().zip(&want).enumerate() {
            assert_eq!(gl, wl, "logits diverge at item {i}");
            assert_eq!(gs.energy, ws.energy, "energy diverges at item {i}");
            assert_eq!(gs.latency_ns, ws.latency_ns);
            assert_eq!(gs.macs, ws.macs);
            assert_eq!(gs.noc_packets, ws.noc_packets);
            assert_eq!(gs.noc_hops, ws.noc_hops);
        }
    }
}

#[test]
fn evaluate_is_batch_size_invariant() {
    let (model, train, test) = tiny_setup();
    let cfg = MacroConfig::default();
    let build = || {
        snn::MacroMlp::from_float(&model, &train, &cfg, LevelMap::DeviceTrue)
    };
    let (acc1, st1) = build().evaluate_batched(&test, 1);
    let (acc8, st8) = build().evaluate_batched(&test, 8);
    let (acc_def, st_def) = build().evaluate(&test);
    assert_eq!(acc1, acc8);
    assert_eq!(acc1, acc_def);
    assert_eq!(st1.energy, st8.energy);
    assert_eq!(st1.energy, st_def.energy);
    assert_eq!(st1.latency_ns, st8.latency_ns);
    assert_eq!(st1.macs, st8.macs);
}

#[test]
fn engine_choice_is_invisible_end_to_end() {
    // DESIGN.md S17: Dense and EventList are interchangeable bit for
    // bit through the whole MLP stack — tile pools and fabric alike —
    // and the Auto default (quantized on these ideal arrays) cannot
    // move accuracy.
    let (model, train, test) = tiny_setup();
    let mk = |engine: MvmEngine| MacroConfig {
        engine,
        ..MacroConfig::default()
    };
    let xs: Vec<Vec<u32>> = (0..9).map(|i| test.features_u8(i)).collect();

    // Tile pools.
    let cfg_d = mk(MvmEngine::Dense);
    let cfg_e = mk(MvmEngine::EventList);
    let mut dense =
        snn::MacroMlp::from_float(&model, &train, &cfg_d, LevelMap::DeviceTrue);
    let mut evlist =
        snn::MacroMlp::from_float(&model, &train, &cfg_e, LevelMap::DeviceTrue);
    for ((dl, ds), (el, es)) in
        dense.forward_batch(&xs).iter().zip(&evlist.forward_batch(&xs))
    {
        assert_eq!(dl, el, "tile-pool logits diverge across engines");
        assert_eq!(ds.energy, es.energy);
        assert_eq!(ds.latency_ns, es.latency_ns);
        assert_eq!(ds.active_rows, es.active_rows);
    }

    // Fabric deployment.
    let mut fd =
        snn::MacroMlp::from_float(&model, &train, &cfg_d, LevelMap::DeviceTrue)
            .attach_fabric(&cfg_d, FabricConfig::square(2))
            .unwrap();
    let mut fe =
        snn::MacroMlp::from_float(&model, &train, &cfg_e, LevelMap::DeviceTrue)
            .attach_fabric(&cfg_e, FabricConfig::square(2))
            .unwrap();
    let (acc_d, st_d) = fd.evaluate(&test);
    let (acc_e, st_e) = fe.evaluate(&test);
    assert_eq!(acc_d, acc_e, "fabric accuracy diverges across engines");
    assert_eq!(st_d.energy, st_e.energy);
    assert_eq!(st_d.active_rows, st_e.active_rows);
    assert_eq!(st_d.noc_packets, st_e.noc_packets);

    // Auto (→ quantized here): exact integer math, accuracy in family.
    let cfg_a = mk(MvmEngine::Auto);
    let mut auto_mlp =
        snn::MacroMlp::from_float(&model, &train, &cfg_a, LevelMap::DeviceTrue);
    let (acc_a, _) = auto_mlp.evaluate(&test);
    let (acc_ref, _) = dense.evaluate(&test);
    assert!(
        (acc_a - acc_ref).abs() < 0.05,
        "auto {acc_a} vs dense {acc_ref}"
    );
}

#[test]
fn fabric_grid_shapes_change_routing_not_results() {
    // Same model on two different meshes: identical predictions, but
    // more spread-out placement → more hops.
    let (model, train, test) = tiny_setup();
    let cfg = MacroConfig::default();
    let eval = |f: FabricConfig| {
        let mut mm = snn::MacroMlp::from_float(
            &model,
            &train,
            &cfg,
            LevelMap::DeviceTrue,
        )
        .attach_fabric(&cfg, f)
        .unwrap();
        mm.evaluate(&test)
    };
    let (acc_small, st_small) = eval(FabricConfig::square(2));
    let (acc_big, st_big) = eval(FabricConfig {
        io_tile: (3, 3), // far corner: every route lengthens
        ..FabricConfig::square(4)
    });
    assert_eq!(acc_small, acc_big);
    assert!(st_big.noc_hops > st_small.noc_hops);
}

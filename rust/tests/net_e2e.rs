//! Network front end (DESIGN.md S23) — cross-level acceptance over
//! real loopback TCP.
//!
//! Pins the S23 bars end-to-end:
//!
//! * hostile bytes (bad JSON, bad UTF-8, unknown types/fields, bogus
//!   length prefixes, mid-frame disconnects) get clean error
//!   responses where the framing survives and clean disconnect
//!   accounting where it cannot — the server never dies;
//! * stream inference through the wire is *bitwise identical* to the
//!   in-process [`StreamServer`] path on the same spec and frames;
//! * queue-full sheds cross the wire with the `retry_after` backoff
//!   hint, and a wire `drain` closes every live connection on a frame
//!   boundary with a clean report.

use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use spikemram::config::{
    FabricConfig, LevelMap, MacroConfig, StreamConfig,
};
use spikemram::net::{
    read_frame, write_frame, NetBackend, NetClient, NetServer, Request,
    Response, WireError, MAX_FRAME_BYTES, SHED_QUEUE_FULL,
};
use spikemram::snn::{Dataset, Mlp};
use spikemram::stream::{
    FrameEncoder, StreamServer, StreamServerConfig, StreamSpec, TemporalCode,
};
use spikemram::util::json::{self, Json};

const T_STEPS: usize = 4;

fn spec(seed: u64) -> StreamSpec {
    StreamSpec {
        model: Mlp::new(seed ^ 0x7),
        calib: Dataset::generate(24, seed),
        mcfg: MacroConfig::default(),
        fabric: FabricConfig::square(2),
        level_map: LevelMap::DeviceTrue,
        stream: StreamConfig {
            t_steps: T_STEPS,
            ..StreamConfig::default()
        },
    }
}

fn frames(seed: u64) -> Vec<Vec<u32>> {
    let data = Dataset::generate(2, seed ^ 0x11);
    let enc = FrameEncoder::new(TemporalCode::Rate, T_STEPS, 255);
    enc.encode_frames(&data.features_u8(0))
}

/// Boot a fresh stream backend behind a fresh wire server on loopback.
fn boot(seed: u64, scfg: StreamServerConfig) -> (NetServer, String) {
    let backend =
        StreamServer::start(spec(seed), scfg).expect("stream backend");
    let net = NetServer::start(NetBackend::Stream(backend), "127.0.0.1:0")
        .expect("bind loopback");
    let addr = net.addr().to_string();
    (net, addr)
}

fn drain_and_join(net: NetServer, addr: &str) {
    let mut ctl = NetClient::connect(addr).expect("drain connect");
    let (_ms, _shed, clean) = ctl.drain(10_000.0).expect("drain");
    assert!(clean, "drain with nothing in flight must be clean");
    net.wait();
}

/// Wait (bounded) until `metric` of the server's snapshot reaches at
/// least `want` — disconnect accounting is asynchronous to the client's
/// view of the socket.
fn await_counter(net: &NetServer, metric: &str, want: u64) -> u64 {
    let m = net.metrics();
    let t0 = Instant::now();
    loop {
        let snap = m.snapshot();
        let got = match metric {
            "wire_requests" => snap.wire_requests,
            "wire_sheds" => snap.wire_sheds,
            "wire_disconnects" => snap.wire_disconnects,
            "wire_malformed" => snap.wire_malformed,
            other => panic!("unknown counter {other}"),
        };
        if got >= want {
            return got;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "{metric} stuck at {got}, want >= {want}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Write one raw frame (length prefix + body bytes, no JSON checks).
fn write_raw(sock: &mut TcpStream, body: &[u8]) {
    sock.write_all(&(body.len() as u32).to_be_bytes()).unwrap();
    sock.write_all(body).unwrap();
    sock.flush().unwrap();
}

fn read_response(sock: &mut TcpStream) -> Response {
    let j = read_frame(sock).expect("response frame");
    Response::from_json(&j).expect("decodable response")
}

#[test]
fn hostile_frames_get_errors_and_the_connection_survives() {
    let (net, addr) = boot(31, StreamServerConfig::default());
    let mut sock = TcpStream::connect(&addr).expect("connect");

    // 1. Framed non-JSON garbage → error response, connection lives.
    write_raw(&mut sock, b"this is not json");
    match read_response(&mut sock) {
        Response::Error { msg } => {
            assert!(!msg.is_empty(), "error carries a reason")
        }
        other => panic!("expected error, got {other:?}"),
    }
    // 2. Framed invalid UTF-8 → error response, connection lives.
    write_raw(&mut sock, &[0xff, 0xfe, 0xfd]);
    assert!(matches!(
        read_response(&mut sock),
        Response::Error { .. }
    ));
    // 3. Valid JSON, unknown request type.
    write_raw(&mut sock, br#"{"type":"fire_missiles"}"#);
    match read_response(&mut sock) {
        Response::Error { msg } => {
            assert!(msg.contains("unknown request type"), "{msg}")
        }
        other => panic!("expected error, got {other:?}"),
    }
    // 4. Known type with an unknown extra field — strict decoding.
    write_raw(&mut sock, br#"{"type":"open_session","evil":1}"#);
    match read_response(&mut sock) {
        Response::Error { msg } => {
            assert!(msg.contains("unknown field"), "{msg}")
        }
        other => panic!("expected error, got {other:?}"),
    }
    // 5. Nesting past the frame depth cap.
    let deep = "[".repeat(64) + &"]".repeat(64);
    write_raw(&mut sock, deep.as_bytes());
    assert!(matches!(
        read_response(&mut sock),
        Response::Error { .. }
    ));
    // 6. Well-formed request with an out-of-range event row: rejected
    //    with an error response, never a worker panic.
    write_raw(
        &mut sock,
        br#"{"type":"stream_frame","session":0,"events":[99999]}"#,
    );
    match read_response(&mut sock) {
        Response::Error { msg } => {
            assert!(msg.contains("out of range"), "{msg}")
        }
        other => panic!("expected error, got {other:?}"),
    }

    // After all that abuse the same connection still serves real work.
    write_frame(&mut sock, &Request::OpenSession.to_json()).unwrap();
    let session = match read_response(&mut sock) {
        Response::SessionOpen { session } => session,
        other => panic!("expected session_open, got {other:?}"),
    };
    let fs = frames(31);
    write_frame(
        &mut sock,
        &Request::StreamFrame {
            session,
            events: fs[0].clone(),
        }
        .to_json(),
    )
    .unwrap();
    match read_response(&mut sock) {
        Response::Frame { t, .. } => assert_eq!(t, 1),
        other => panic!("expected frame, got {other:?}"),
    }

    // Malformed accounting: codec rejections (1, 2, 5), decode
    // rejections (3, 4), and the pre-submit event validation (6).
    assert!(await_counter(&net, "wire_malformed", 6) >= 6);
    // Requests count only frames that decoded into a `Request`: the
    // bad-events stream_frame (6), the open, and the good frame.
    assert!(await_counter(&net, "wire_requests", 3) >= 3);

    drop(sock);
    drain_and_join(net, &addr);
}

#[test]
fn hostile_drain_deadline_is_an_error_not_a_wedged_server() {
    // Regression: deadline_ms=1e23 overflows Duration::from_secs_f64.
    // Before the protocol bound, the panic fired *after* the backend
    // was take()n, permanently wedging the server (every request shed
    // as "draining", wait() never returning).
    let (net, addr) = boot(97, StreamServerConfig::default());
    let mut sock = TcpStream::connect(&addr).expect("connect");
    write_raw(&mut sock, br#"{"type":"drain","deadline_ms":1e23}"#);
    match read_response(&mut sock) {
        Response::Error { msg } => {
            assert!(msg.contains("deadline_ms"), "{msg}")
        }
        other => panic!("expected error, got {other:?}"),
    }
    // The backend must still be installed: the same connection opens a
    // session and serves a frame...
    write_frame(&mut sock, &Request::OpenSession.to_json()).unwrap();
    let session = match read_response(&mut sock) {
        Response::SessionOpen { session } => session,
        other => panic!("expected session_open, got {other:?}"),
    };
    let fs = frames(97);
    write_frame(
        &mut sock,
        &Request::StreamFrame {
            session,
            events: fs[0].clone(),
        }
        .to_json(),
    )
    .unwrap();
    assert!(matches!(
        read_response(&mut sock),
        Response::Frame { .. }
    ));
    drop(sock);
    // ...and a sane drain still stops the server cleanly.
    drain_and_join(net, &addr);
}

#[test]
fn oversized_prefix_hangs_up_but_the_server_survives() {
    let (net, addr) = boot(33, StreamServerConfig::default());
    let mut sock = TcpStream::connect(&addr).expect("connect");
    sock.write_all(&((MAX_FRAME_BYTES as u32) + 1).to_be_bytes())
        .unwrap();
    sock.write_all(b"xxxx").unwrap();
    sock.flush().unwrap();
    // The server explains, then hangs up: one error response, then EOF.
    match read_response(&mut sock) {
        Response::Error { msg } => assert!(msg.contains("exceeds"), "{msg}"),
        other => panic!("expected error, got {other:?}"),
    }
    match read_frame(&mut sock) {
        Err(WireError::Closed) => {}
        other => panic!("expected EOF after oversized prefix, got {other:?}"),
    }
    assert!(await_counter(&net, "wire_malformed", 1) >= 1);
    assert!(await_counter(&net, "wire_disconnects", 1) >= 1);

    // A fresh connection still works — the *server* survived.
    let mut c = NetClient::connect(&addr).expect("reconnect");
    let s = c.open_session().expect("open after abuse");
    let fs = frames(33);
    let resp = c.stream_frame(s, fs[0].clone()).expect("frame");
    assert!(matches!(resp, Response::Frame { .. }));
    drain_and_join(net, &addr);
}

#[test]
fn midframe_disconnect_counts_as_wire_disconnect() {
    let (net, addr) = boot(37, StreamServerConfig::default());
    {
        let mut sock = TcpStream::connect(&addr).expect("connect");
        // Promise 100 bytes, deliver 3, vanish.
        sock.write_all(&100u32.to_be_bytes()).unwrap();
        sock.write_all(b"abc").unwrap();
        sock.flush().unwrap();
    } // dropped: RST/FIN mid-frame
    assert!(await_counter(&net, "wire_disconnects", 1) >= 1);
    // Orderly close on a frame boundary is NOT a disconnect.
    let before = net.metrics().snapshot().wire_disconnects;
    {
        let _sock = TcpStream::connect(&addr).expect("connect");
        std::thread::sleep(Duration::from_millis(80));
    }
    std::thread::sleep(Duration::from_millis(120));
    assert_eq!(
        net.metrics().snapshot().wire_disconnects,
        before,
        "clean EOF must not count as a disconnect"
    );
    drain_and_join(net, &addr);
}

#[test]
fn wire_stream_inference_is_bit_identical_to_in_process() {
    let seed = 41;
    let fs = frames(seed);
    assert_eq!(fs.len(), T_STEPS);

    // In-process reference: one session through StreamServer directly.
    let local = StreamServer::start(
        spec(seed),
        StreamServerConfig::default(),
    )
    .expect("local server");
    let ls = local.open_session();
    let mut local_replies = Vec::new();
    for f in &fs {
        local_replies.push(local.frame(ls, f.clone()));
    }
    let local_final = local.finish(ls);
    let _ = local.shutdown();

    // Wire path: same spec/seed, same frames, through TCP + JSON.
    let (net, addr) = boot(seed, StreamServerConfig::default());
    let mut c = NetClient::connect(&addr).expect("connect");
    let ws = c.open_session().expect("open");
    for (i, f) in fs.iter().enumerate() {
        match c.stream_frame(ws, f.clone()).expect("frame") {
            Response::Frame {
                t, out_v, label, ..
            } => {
                let want = &local_replies[i];
                assert_eq!(t as usize, want.t, "step {i}");
                assert_eq!(label as usize, want.label, "step {i}");
                // Bitwise: the JSON number round-trip must not perturb
                // a single ULP of the membrane state.
                assert_eq!(
                    out_v.len(),
                    want.out_v.len(),
                    "step {i} out_v arity"
                );
                for (a, b) in out_v.iter().zip(&want.out_v) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "step {i}: wire {a:?} != local {b:?}"
                    );
                }
            }
            other => panic!("expected frame, got {other:?}"),
        }
    }
    let (t, out_v, label) = c.close_session(ws).expect("close");
    assert_eq!(t as usize, local_final.t);
    assert_eq!(label as usize, local_final.label);
    for (a, b) in out_v.iter().zip(&local_final.out_v) {
        assert_eq!(a.to_bits(), b.to_bits(), "final membranes");
    }
    drain_and_join(net, &addr);
}

#[test]
fn queue_full_sheds_carry_retry_after_over_the_wire() {
    // 1 worker with a 1-deep queue, hammered by 6 synchronous
    // connections: most submissions find the slot taken and must come
    // back as shed responses with a positive retry_after hint.
    let (net, addr) = boot(
        43,
        StreamServerConfig {
            workers: 1,
            queue_cap: 1,
            ..StreamServerConfig::default()
        },
    );
    let fs = frames(43);
    let shed_seen: Vec<(u64, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let addr = addr.clone();
                let fs = fs.clone();
                s.spawn(move || {
                    let mut c =
                        NetClient::connect(&addr).expect("connect");
                    let sess = c.open_session().expect("open");
                    let (mut served, mut shed) = (0u64, 0u64);
                    for i in 0..40 {
                        match c
                            .stream_frame(sess, fs[i % fs.len()].clone())
                            .expect("frame call")
                        {
                            Response::Frame { .. } => served += 1,
                            Response::Shed {
                                reason,
                                retry_after_ms,
                            } => {
                                assert_eq!(reason, SHED_QUEUE_FULL);
                                assert!(
                                    retry_after_ms > 0.0,
                                    "hint must be positive"
                                );
                                shed += 1;
                            }
                            other => panic!("unexpected {other:?}"),
                        }
                    }
                    c.close_session(sess).expect("close");
                    (served, shed)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let total_shed: u64 = shed_seen.iter().map(|&(_, s)| s).sum();
    let total_served: u64 = shed_seen.iter().map(|&(s, _)| s).sum();
    assert!(total_served > 0, "some frames must be served");
    assert!(
        total_shed > 0,
        "6 hammering connections over a 1-deep queue must shed"
    );
    let snap = net.metrics().snapshot();
    assert_eq!(snap.wire_sheds, total_shed, "wire shed accounting");
    drain_and_join(net, &addr);
}

#[test]
fn wire_drain_closes_live_connections_cleanly() {
    let (net, addr) = boot(47, StreamServerConfig::default());
    // A live raw connection with an open session, idle mid-stream —
    // raw so the shutdown can be classified byte-exactly below.
    let mut bystander = TcpStream::connect(&addr).expect("connect");
    write_frame(&mut bystander, &Request::OpenSession.to_json()).unwrap();
    let sess = match read_response(&mut bystander) {
        Response::SessionOpen { session } => session,
        other => panic!("expected session_open, got {other:?}"),
    };
    let fs = frames(47);
    write_frame(
        &mut bystander,
        &Request::StreamFrame {
            session: sess,
            events: fs[0].clone(),
        }
        .to_json(),
    )
    .unwrap();
    assert!(matches!(
        read_response(&mut bystander),
        Response::Frame { .. }
    ));

    // Another connection drains the server.
    let mut ctl = NetClient::connect(&addr).expect("ctl connect");
    let (_drain_ms, shed, clean) = ctl.drain(10_000.0).expect("drain");
    assert_eq!(shed, 0, "nothing was in flight");
    assert!(clean);

    // The bystander now sees exactly one of two clean endings, and
    // never a mid-frame truncation or a half-served reply:
    //  * the handler noticed the stop flag first → orderly EOF on the
    //    frame boundary (`WireError::Closed`);
    //  * the handler read our request during the stop window → one
    //    `shed`/`draining` response, then the orderly EOF.
    let wrote = write_frame(
        &mut bystander,
        &Request::StreamFrame {
            session: sess,
            events: fs[1].clone(),
        }
        .to_json(),
    );
    if wrote.is_ok() {
        match read_frame(&mut bystander) {
            Err(WireError::Closed) => {}
            Ok(j) => {
                match Response::from_json(&j).expect("decodable") {
                    Response::Shed { reason, .. } => {
                        assert_eq!(reason, "draining")
                    }
                    other => panic!("half-served after drain: {other:?}"),
                }
                // ... and then the orderly EOF.
                match read_frame(&mut bystander) {
                    Err(WireError::Closed) => {}
                    other => panic!("expected EOF after drain: {other:?}"),
                }
            }
            Err(e) => panic!("unclean close after drain: {e}"),
        }
    }
    // (wrote.is_err() means the socket was already closed — also clean.)
    net.wait();
}

#[test]
fn post_drain_connections_are_refused_or_shed() {
    let (net, addr) = boot(53, StreamServerConfig::default());
    let mut ctl = NetClient::connect(&addr).expect("connect");
    let (_ms, _shed, clean) = ctl.drain(10_000.0).expect("drain");
    assert!(clean);
    net.wait();
    // The listener is gone: a fresh connect must fail (or be reset on
    // first use) — never hang.
    let sock = TcpStream::connect(&addr);
    if let Ok(mut s) = sock {
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let r = write_frame(&mut s, &Request::MetricsQuery.to_json())
            .and_then(|_| {
                read_frame(&mut s)
                    .map(|_| ())
                    .map_err(|e| std::io::Error::other(e.to_string()))
            });
        assert!(r.is_err(), "post-shutdown request must not be served");
    }
}

#[test]
fn metrics_query_round_trips_snapshot_json() {
    let (net, addr) = boot(59, StreamServerConfig::default());
    let mut c = NetClient::connect(&addr).expect("connect");
    let sess = c.open_session().expect("open");
    let fs = frames(59);
    for f in &fs {
        let _ = c.stream_frame(sess, f.clone()).expect("frame");
    }
    c.close_session(sess).expect("close");
    let snap = c.metrics().expect("metrics over the wire");
    // The wire snapshot is the MetricsSnapshot::to_json document; it
    // must survive a serialize→parse round trip and report the served
    // frames and the wire counters.
    let reparsed = json::parse(&snap.to_string()).expect("round trip");
    assert_eq!(reparsed, snap);
    let requests = snap
        .get("requests")
        .and_then(|v| v.as_f64())
        .expect("requests field");
    assert!(requests >= fs.len() as f64);
    let wire_requests = snap
        .get("net")
        .and_then(|n| n.get("wire_requests"))
        .and_then(|v| v.as_f64())
        .expect("net.wire_requests field");
    assert!(wire_requests >= (fs.len() + 2) as f64);
    match snap.get("net").and_then(|n| n.get("wire_malformed")) {
        Some(Json::Num(n)) => assert_eq!(*n, 0.0),
        other => panic!("net.wire_malformed missing: {other:?}"),
    }
    drain_and_join(net, &addr);
}

//! Smoke test: every `repro/` entry point stays executable (DESIGN.md §5).
//!
//! One call per experiment module — fig3–fig7, table1/table2, ablations,
//! scaling, fabric — with deliberately tiny configs, so the documented claims
//! (`spikemram table1|fig7a|…` and the README quickstart) cannot rot
//! without CI noticing. Result files go to a throwaway directory.

use spikemram::config::MacroConfig;
use spikemram::repro::{
    ablations, fabric, fig3, fig5, fig6, fig7, reliability, report, scaling,
    stream, table1, table2,
};

fn results_to_tmp() {
    // set_var exactly once per process: concurrent setenv while another
    // thread getenvs is a libc-level race, and these tests run in parallel.
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        std::env::set_var("SPIKEMRAM_RESULTS", "/tmp/spikemram_smoke_results")
    });
}

#[test]
fn table1_renders_key_parameters() {
    let s = table1::table1(&MacroConfig::default());
    assert!(s.contains("Table I"));
    assert!(s.contains("128×128"));
}

#[test]
fn fig3_smu_transient_runs() {
    results_to_tmp();
    let f = fig3::run(&MacroConfig::default(), 16);
    assert!((f.flag_duration_ns - 3.2).abs() < 1e-9);
    assert!(fig3::render(&f).contains("Fig 3(c)"));
}

#[test]
fn fig5_conversion_transient_runs() {
    results_to_tmp();
    let f = fig5::run(&MacroConfig::default());
    assert!((f.t_out_ns - f.t_out_eq2_ns).abs() < 1e-9);
    assert!(fig5::render(&f).contains("Fig 5"));
}

#[test]
fn fig6_power_and_sensing_run_tiny() {
    results_to_tmp();
    let cfg = MacroConfig::default();
    let a = fig6::run_fig6a(&cfg, 3, 7);
    assert!(a.tops_per_watt > 100.0, "{}", a.tops_per_watt);
    assert!(fig6::render_fig6a(&a).contains("Fig 6(a)"));
    let b = fig6::run_fig6b(&cfg);
    assert_eq!(b.rows.len(), 4);
    assert!(fig6::render_fig6b(&b).contains("Fig 6(b)"));
}

#[test]
fn fig7_linearity_and_droop_run_tiny() {
    results_to_tmp();
    let cfg = MacroConfig::default();
    let a = fig7::run_fig7a(&cfg, 128, 7);
    assert!(a.fit.r2 > 0.999, "R² {}", a.fit.r2);
    let b = fig7::run_fig7b(&cfg, fig7::FIG7B_ACTIVE_ROWS);
    assert!(b.droop_10ns > b.droop_5ns);
    assert!(fig7::render_fig7b(&b).contains("Fig 7(b)"));
}

#[test]
fn table2_comparison_runs_tiny() {
    let t2 = table2::run(&MacroConfig::default(), 2, 7);
    assert_eq!(t2.rows.len(), 6);
    assert!(table2::render(&t2).contains("This Work"));
}

#[test]
fn ablations_run_tiny() {
    let rows = ablations::run(7, 1);
    assert!(rows.len() >= 6, "{}", rows.len());
    assert!(ablations::render(&rows).contains("Ablations"));
}

#[test]
fn scaling_study_runs() {
    results_to_tmp();
    let pts = scaling::run(&MacroConfig::default());
    assert_eq!(pts.len(), 4);
    assert!(scaling::render(&pts).contains("512×512"));
}

#[test]
fn fabric_scaling_sweep_runs_tiny() {
    results_to_tmp();
    let pts = fabric::run_points(&MacroConfig::default(), &[1, 2], 7, 1);
    assert_eq!(pts.len(), 2);
    assert!(pts[1].tops > pts[0].tops);
    assert!(fabric::render(&pts).contains("2×2"));
}

#[test]
fn stream_sweep_runs_tiny() {
    results_to_tmp();
    let pts =
        stream::run_points(&MacroConfig::default(), &[1, 2], 7, 60, 10, 2);
    assert_eq!(pts.len(), 2);
    assert!(pts[0].energy_pj <= pts[1].energy_pj);
    assert!(stream::render(&pts).contains("EX3"));
}

#[test]
fn reliability_sweep_runs_tiny() {
    results_to_tmp();
    let pts = reliability::run_points(
        &MacroConfig::default(),
        &[0.0],
        7,
        60,
        10,
        2,
        4,
    );
    assert_eq!(pts.len(), 1);
    assert_eq!(pts[0].flips, 0, "no drift at uptime 0");
    assert_eq!(pts[0].acc_unscrubbed, pts[0].acc_scrubbed);
    assert!(reliability::render(&pts).contains("EX4"));
}

#[test]
fn report_roundtrip_in_smoke_dir() {
    results_to_tmp();
    report::save("smoke/probe.txt", "ok");
    assert_eq!(report::load("smoke/probe.txt").as_deref(), Some("ok"));
    assert!(report::exists("smoke/probe.txt"));
}

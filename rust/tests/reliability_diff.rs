//! Differential engine tests under injected faults (DESIGN.md S19).
//!
//! The fault runtime mutates live crossbars; these tests pin the
//! engine-level contracts that keep serving correct while it does:
//!
//! * Dense and EventList stay *bitwise* interchangeable on the same
//!   corrupted array — faults change the answer, never the engines'
//!   agreement;
//! * `MvmEngine::Auto` degrades away from the Quantized level-plane
//!   engine the moment die-to-die variation breaks the uniform-levels
//!   gate, falling back to a general engine instead of panicking, and
//!   the fallback matches forced Dense bitwise;
//! * a completed scrub of a drift-only array restores the pristine
//!   deployment bit-for-bit — codes, conductances, and MVM outputs —
//!   while paying real write energy and wear;
//! * pure conductance-gain drift (S22) is the dual failure mode: codes
//!   never move, so a scrub is a bitwise no-op that writes nothing and
//!   costs nothing, while online λ recalibration is the mechanism that
//!   actually restores the accuracy proxy.

use spikemram::config::{
    FabricConfig, LevelMap, MacroConfig, MvmEngine, StreamConfig,
};
use spikemram::device::{FaultPlan, FaultState, RetentionParams, SotWriteParams};
use spikemram::macro_model::{CimMacro, EngineUsed};
use spikemram::snn::{mlp, Dataset};
use spikemram::stream::{FrameEncoder, SpikingMlp, TemporalCode};
use spikemram::util::rng::Rng;

fn programmed(seed: u64, engine: MvmEngine) -> CimMacro {
    let cfg = MacroConfig {
        engine,
        ..MacroConfig::default()
    };
    let mut m = CimMacro::new(cfg);
    let mut rng = Rng::new(seed);
    let codes: Vec<u8> = (0..128 * 128).map(|_| rng.below(4) as u8).collect();
    m.program(&codes);
    m
}

/// Drive the identical harsh fault sequence (d2d variation + stuck
/// cells at deploy, then one retention drift round) into a macro.
fn corrupt(m: &mut CimMacro, plan: FaultPlan) -> usize {
    let mut fs = FaultState::new(plan, 0);
    fs.deploy(&mut m.xbar);
    fs.advance(&mut m.xbar, plan.retention.tau_ret_ns() / 10.0)
}

#[test]
fn dense_and_event_list_agree_bitwise_on_a_corrupted_array() {
    let plan = FaultPlan::harsh(91);
    let mut dense = programmed(90, MvmEngine::Dense);
    let mut evlist = programmed(90, MvmEngine::EventList);
    let fa = corrupt(&mut dense, plan);
    let fb = corrupt(&mut evlist, plan);
    assert_eq!(fa, fb, "same plan + index → identical fault sequence");
    assert!(fa > 0, "the stress corner must actually corrupt");
    assert_eq!(dense.xbar.read_codes(), evlist.xbar.read_codes());
    assert_eq!(dense.xbar.conductances(), evlist.xbar.conductances());

    let mut rng = Rng::new(92);
    for density in [0.02, 0.3, 1.0] {
        // Multi-bit inputs: the full 8-bit input range, not just
        // binary spikes.
        let x: Vec<u32> = (0..128)
            .map(|_| {
                if rng.f64() < density {
                    1 + rng.below(255) as u32
                } else {
                    0
                }
            })
            .collect();
        let a = dense.mvm_batch(std::slice::from_ref(&x));
        let b = evlist.mvm_batch(std::slice::from_ref(&x));
        assert_eq!(a.engine_used(), EngineUsed::Dense);
        assert_eq!(b.engine_used(), EngineUsed::EventList);
        let (ra, rb) = (a.result(0), b.result(0));
        assert_eq!(ra.y_mac, rb.y_mac, "density {density}");
        assert_eq!(ra.t_out_ns, rb.t_out_ns);
        assert_eq!(ra.v_charge, rb.v_charge);
        assert_eq!(ra.energy, rb.energy);
    }
}

#[test]
fn auto_degrades_from_quantized_under_d2d_and_matches_dense() {
    let mut auto = programmed(93, MvmEngine::Auto);
    let mut dense = programmed(93, MvmEngine::Dense);
    let dense_x: Vec<u32> = (0..128).map(|r| 1 + (r as u32 * 7) % 255).collect();

    // Healthy array: Auto picks the quantized level-plane engine.
    let r = auto.mvm_batch(std::slice::from_ref(&dense_x));
    assert_eq!(r.engine_used(), EngineUsed::Quantized);

    // Identical harsh faults on both macros: d2d scaling moves the
    // conductances off their level targets.
    let plan = FaultPlan::harsh(94);
    corrupt(&mut auto, plan);
    corrupt(&mut dense, plan);
    assert!(!auto.xbar.uniform_levels(), "d2d must break the gate");

    // Auto must fall back — never panic — and the fallback is one of
    // the exact engines, so it matches forced Dense bitwise.
    let ra = auto.mvm_batch(std::slice::from_ref(&dense_x));
    assert_ne!(ra.engine_used(), EngineUsed::Quantized);
    let rd = dense.mvm_batch(std::slice::from_ref(&dense_x));
    let (a, d) = (ra.result(0), rd.result(0));
    assert_eq!(a.y_mac, d.y_mac);
    assert_eq!(a.t_out_ns, d.t_out_ns);
    assert_eq!(a.energy, d.energy);

    // Sparse traffic under the same faults: Auto's event-list pick is
    // exercised too, still bitwise-equal.
    let mut sparse_x = vec![0u32; 128];
    sparse_x[17] = 200;
    sparse_x[90] = 3;
    let ra = auto.mvm_batch(std::slice::from_ref(&sparse_x));
    assert_eq!(ra.engine_used(), EngineUsed::EventList);
    let rd = dense.mvm_batch(std::slice::from_ref(&sparse_x));
    assert_eq!(ra.result(0).y_mac, rd.result(0).y_mac);
}

#[test]
fn full_scrub_restores_bitwise_identity_with_the_pristine_baseline() {
    let mut pristine = programmed(95, MvmEngine::Auto);
    let mut aged = programmed(95, MvmEngine::Auto);
    let golden = aged.golden_codes();
    assert_eq!(golden, pristine.golden_codes());

    // Drift only: states move, R_P never does.
    let ret = RetentionParams::stress();
    let plan = FaultPlan::drift_only(ret, 96);
    let mut fs = FaultState::new(plan, 0);
    let flips = fs.advance(&mut aged.xbar, ret.tau_ret_ns());
    assert!(flips > 0);
    assert_ne!(aged.xbar.read_codes(), golden);

    let wear_before = aged.xbar.write_pulses;
    let out = fs.scrub(
        &mut aged.xbar,
        &golden,
        &SotWriteParams::default(),
    );
    assert_eq!(out.checked, 128 * 128);
    assert_eq!(out.mismatched, flips);
    assert_eq!(out.repaired, flips, "overdriven verify-write is total");
    assert!(out.energy_fj > 0.0, "scrub writes cost real energy");
    assert!(out.junction_pulses as usize >= flips, "wear is charged");
    assert_eq!(
        aged.xbar.write_pulses,
        wear_before + out.junction_pulses,
        "scrub pulses land on the array's wear counter"
    );

    // Bit-identity: codes, conductances, and the computed answers.
    assert_eq!(aged.xbar.read_codes(), golden);
    assert_eq!(aged.xbar.conductances(), pristine.xbar.conductances());
    let mut rng = Rng::new(97);
    for _ in 0..4 {
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let a = aged.mvm_batch(std::slice::from_ref(&x));
        let p = pristine.mvm_batch(std::slice::from_ref(&x));
        assert_eq!(a.engine_used(), p.engine_used());
        assert_eq!(a.engine_used(), EngineUsed::Quantized, "gate restored");
        let (ra, rp) = (a.result(0), p.result(0));
        assert_eq!(ra.y_mac, rp.y_mac);
        assert_eq!(ra.t_out_ns, rp.t_out_ns);
        assert_eq!(ra.v_charge, rp.v_charge);
        assert_eq!(ra.energy, rp.energy);
    }
}

#[test]
fn pure_gain_drift_is_invisible_to_scrub_and_engines_stay_bitwise_equal() {
    // Frozen retention + a gain walk: the one fault class verify-and-
    // rewrite cannot even *see*, because the stored codes never move.
    let plan = FaultPlan::gain_only(0.3, 101);
    let mut dense = programmed(100, MvmEngine::Dense);
    let mut evlist = programmed(100, MvmEngine::EventList);
    let pristine = programmed(100, MvmEngine::Dense);
    let golden = dense.golden_codes();

    let mut fa = FaultState::new(plan, 0);
    let mut fb = FaultState::new(plan, 0);
    let hour_ns = 3.6e12;
    let mut flips = 0usize;
    for _ in 0..4 {
        flips += fa.advance(&mut dense.xbar, hour_ns);
        flips += fb.advance(&mut evlist.xbar, hour_ns);
    }
    // The frozen corner's flip probability is exactly zero, so the
    // no-flip half of the differential is certain, not statistical.
    assert_eq!(flips, 0, "frozen retention corner must never flip");
    assert_eq!(fa.gain, fb.gain, "same plan + index → identical walk");
    assert_ne!(fa.gain, 1.0, "the gain walk must actually move");
    // Codes intact, analog levels off-nominal: drift the scrubber's
    // verify pass is structurally blind to.
    assert_eq!(dense.xbar.read_codes(), golden);
    assert_ne!(dense.xbar.conductances(), pristine.xbar.conductances());

    // Scrub is a bitwise no-op: nothing detected, nothing rewritten,
    // zero pulses, zero energy, wear counter untouched.
    let cond_before = dense.xbar.conductances();
    let wear_before = dense.xbar.write_pulses;
    let out = fa.scrub(&mut dense.xbar, &golden, &SotWriteParams::default());
    assert_eq!(out.checked, 128 * 128);
    assert_eq!(out.mismatched, 0);
    assert_eq!(out.repaired, 0);
    assert_eq!(out.junction_pulses, 0);
    assert_eq!(out.energy_fj, 0.0, "no rewrites → no write energy");
    assert_eq!(dense.xbar.write_pulses, wear_before);
    assert_eq!(dense.xbar.conductances(), cond_before);

    // The engines remain bitwise interchangeable on the gained array.
    let mut rng = Rng::new(102);
    for _ in 0..3 {
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let a = dense.mvm_batch(std::slice::from_ref(&x));
        let b = evlist.mvm_batch(std::slice::from_ref(&x));
        let (ra, rb) = (a.result(0), b.result(0));
        assert_eq!(ra.y_mac, rb.y_mac);
        assert_eq!(ra.t_out_ns, rb.t_out_ns);
        assert_eq!(ra.v_charge, rb.v_charge);
        assert_eq!(ra.energy, rb.energy);
    }
}

#[test]
fn recalibration_answers_gain_drift_where_scrub_is_a_provable_noop() {
    // Network-level half of the S22 differential: deploy one trained
    // digit model twice, walk only the gain on the second copy, and
    // show (a) a scrub changes *nothing* — outputs bitwise equal before
    // and after — while (b) recalibration re-derives the λ thresholds
    // and keeps the accuracy proxy (label agreement with the pristine
    // deployment) well above the 10-class floor.
    let seed = 201;
    let train = Dataset::generate(64, seed);
    let (model, _) = mlp::train(&train, 3, seed);
    let scfg = StreamConfig::default();
    let deploy = || {
        SpikingMlp::from_float(
            &model,
            &train,
            &MacroConfig::default(),
            FabricConfig::square(2),
            LevelMap::DeviceTrue,
            &scfg,
        )
        .expect("2x2 mesh holds the digit MLP's 4 shards")
    };
    let enc = FrameEncoder::new(TemporalCode::Rate, scfg.t_steps, 255);
    let frames: Vec<Vec<Vec<u32>>> = (0..16)
        .map(|i| enc.encode_frames(&train.features_u8(i)))
        .collect();

    let mut pristine = deploy();
    let pristine_labels: Vec<usize> =
        frames.iter().map(|f| pristine.run(f).label).collect();

    // Same EX6 gain law as the mission clock: σ = 5 %/√h over 4 h.
    let mut drifted = deploy();
    let golden = drifted.snapshot_codes();
    let mut st = drifted.fault_states(FaultPlan::gain_only(0.05, seed));
    drifted.deploy_faults(&mut st);
    let mut flips = 0u64;
    for _ in 0..4 {
        flips += drifted.drift(&mut st, 3.6e12);
    }
    assert_eq!(flips, 0, "gain-only plan: retention is frozen");
    let moved = st
        .iter()
        .flatten()
        .map(|fs| (fs.gain - 1.0).abs())
        .fold(0.0, f64::max);
    assert!(moved > 0.0, "every shard's gain walk starts at exactly 1");

    // (a) Scrub: zero mismatches, zero pulses, and the network's
    // predictions are bitwise unchanged by the attempt.
    let before: Vec<(usize, Vec<f64>)> = frames
        .iter()
        .map(|f| {
            let r = drifted.run(f);
            (r.label, r.out_v)
        })
        .collect();
    let out = drifted.scrub(&mut st, &golden, &SotWriteParams::default());
    assert!(out.checked > 0);
    assert_eq!(out.mismatched, 0);
    assert_eq!(out.repaired, 0);
    assert_eq!(out.junction_pulses, 0);
    assert_eq!(out.energy_fj, 0.0);
    let after: Vec<(usize, Vec<f64>)> = frames
        .iter()
        .map(|f| {
            let r = drifted.run(f);
            (r.label, r.out_v)
        })
        .collect();
    assert_eq!(before, after, "scrub is a no-op under pure gain drift");

    // (b) Recalibration: λ per hidden stage re-derived against the
    // gained arrays; agreement with the pristine deployment stays far
    // above chance. (The floor is loose on purpose: each shard walks an
    // independent gain stream, and one λ per stage cannot undo a
    // *differential* shard gain — EX6 measures that residual.)
    let calib: Vec<Vec<Vec<u32>>> = frames.iter().take(8).cloned().collect();
    let lambdas = drifted.recalibrate(&calib, scfg.theta_pct);
    assert!(!lambdas.is_empty());
    assert!(lambdas.iter().all(|l| l.is_finite() && *l > 0.0));
    let mut agree = 0usize;
    for (f, &want) in frames.iter().zip(&pristine_labels) {
        if drifted.run(f).label == want {
            agree += 1;
        }
    }
    assert!(
        agree * 10 >= frames.len() * 4,
        "recalibrated agreement {agree}/{} under the 40 % floor",
        frames.len()
    );
}

//! Differential engine tests under injected faults (DESIGN.md S19).
//!
//! The fault runtime mutates live crossbars; these tests pin the
//! engine-level contracts that keep serving correct while it does:
//!
//! * Dense and EventList stay *bitwise* interchangeable on the same
//!   corrupted array — faults change the answer, never the engines'
//!   agreement;
//! * `MvmEngine::Auto` degrades away from the Quantized level-plane
//!   engine the moment die-to-die variation breaks the uniform-levels
//!   gate, falling back to a general engine instead of panicking, and
//!   the fallback matches forced Dense bitwise;
//! * a completed scrub of a drift-only array restores the pristine
//!   deployment bit-for-bit — codes, conductances, and MVM outputs —
//!   while paying real write energy and wear.

use spikemram::config::{MacroConfig, MvmEngine};
use spikemram::device::{FaultPlan, FaultState, RetentionParams, SotWriteParams};
use spikemram::macro_model::{CimMacro, EngineUsed};
use spikemram::util::rng::Rng;

fn programmed(seed: u64, engine: MvmEngine) -> CimMacro {
    let cfg = MacroConfig {
        engine,
        ..MacroConfig::default()
    };
    let mut m = CimMacro::new(cfg);
    let mut rng = Rng::new(seed);
    let codes: Vec<u8> = (0..128 * 128).map(|_| rng.below(4) as u8).collect();
    m.program(&codes);
    m
}

/// Drive the identical harsh fault sequence (d2d variation + stuck
/// cells at deploy, then one retention drift round) into a macro.
fn corrupt(m: &mut CimMacro, plan: FaultPlan) -> usize {
    let mut fs = FaultState::new(plan, 0);
    fs.deploy(&mut m.xbar);
    fs.advance(&mut m.xbar, plan.retention.tau_ret_ns() / 10.0)
}

#[test]
fn dense_and_event_list_agree_bitwise_on_a_corrupted_array() {
    let plan = FaultPlan::harsh(91);
    let mut dense = programmed(90, MvmEngine::Dense);
    let mut evlist = programmed(90, MvmEngine::EventList);
    let fa = corrupt(&mut dense, plan);
    let fb = corrupt(&mut evlist, plan);
    assert_eq!(fa, fb, "same plan + index → identical fault sequence");
    assert!(fa > 0, "the stress corner must actually corrupt");
    assert_eq!(dense.xbar.read_codes(), evlist.xbar.read_codes());
    assert_eq!(dense.xbar.conductances(), evlist.xbar.conductances());

    let mut rng = Rng::new(92);
    for density in [0.02, 0.3, 1.0] {
        // Multi-bit inputs: the full 8-bit input range, not just
        // binary spikes.
        let x: Vec<u32> = (0..128)
            .map(|_| {
                if rng.f64() < density {
                    1 + rng.below(255) as u32
                } else {
                    0
                }
            })
            .collect();
        let a = dense.mvm_batch(std::slice::from_ref(&x));
        let b = evlist.mvm_batch(std::slice::from_ref(&x));
        assert_eq!(a.engine_used(), EngineUsed::Dense);
        assert_eq!(b.engine_used(), EngineUsed::EventList);
        let (ra, rb) = (a.result(0), b.result(0));
        assert_eq!(ra.y_mac, rb.y_mac, "density {density}");
        assert_eq!(ra.t_out_ns, rb.t_out_ns);
        assert_eq!(ra.v_charge, rb.v_charge);
        assert_eq!(ra.energy, rb.energy);
    }
}

#[test]
fn auto_degrades_from_quantized_under_d2d_and_matches_dense() {
    let mut auto = programmed(93, MvmEngine::Auto);
    let mut dense = programmed(93, MvmEngine::Dense);
    let dense_x: Vec<u32> = (0..128).map(|r| 1 + (r as u32 * 7) % 255).collect();

    // Healthy array: Auto picks the quantized level-plane engine.
    let r = auto.mvm_batch(std::slice::from_ref(&dense_x));
    assert_eq!(r.engine_used(), EngineUsed::Quantized);

    // Identical harsh faults on both macros: d2d scaling moves the
    // conductances off their level targets.
    let plan = FaultPlan::harsh(94);
    corrupt(&mut auto, plan);
    corrupt(&mut dense, plan);
    assert!(!auto.xbar.uniform_levels(), "d2d must break the gate");

    // Auto must fall back — never panic — and the fallback is one of
    // the exact engines, so it matches forced Dense bitwise.
    let ra = auto.mvm_batch(std::slice::from_ref(&dense_x));
    assert_ne!(ra.engine_used(), EngineUsed::Quantized);
    let rd = dense.mvm_batch(std::slice::from_ref(&dense_x));
    let (a, d) = (ra.result(0), rd.result(0));
    assert_eq!(a.y_mac, d.y_mac);
    assert_eq!(a.t_out_ns, d.t_out_ns);
    assert_eq!(a.energy, d.energy);

    // Sparse traffic under the same faults: Auto's event-list pick is
    // exercised too, still bitwise-equal.
    let mut sparse_x = vec![0u32; 128];
    sparse_x[17] = 200;
    sparse_x[90] = 3;
    let ra = auto.mvm_batch(std::slice::from_ref(&sparse_x));
    assert_eq!(ra.engine_used(), EngineUsed::EventList);
    let rd = dense.mvm_batch(std::slice::from_ref(&sparse_x));
    assert_eq!(ra.result(0).y_mac, rd.result(0).y_mac);
}

#[test]
fn full_scrub_restores_bitwise_identity_with_the_pristine_baseline() {
    let mut pristine = programmed(95, MvmEngine::Auto);
    let mut aged = programmed(95, MvmEngine::Auto);
    let golden = aged.golden_codes();
    assert_eq!(golden, pristine.golden_codes());

    // Drift only: states move, R_P never does.
    let ret = RetentionParams::stress();
    let plan = FaultPlan::drift_only(ret, 96);
    let mut fs = FaultState::new(plan, 0);
    let flips = fs.advance(&mut aged.xbar, ret.tau_ret_ns());
    assert!(flips > 0);
    assert_ne!(aged.xbar.read_codes(), golden);

    let wear_before = aged.xbar.write_pulses;
    let out = fs.scrub(
        &mut aged.xbar,
        &golden,
        &SotWriteParams::default(),
    );
    assert_eq!(out.checked, 128 * 128);
    assert_eq!(out.mismatched, flips);
    assert_eq!(out.repaired, flips, "overdriven verify-write is total");
    assert!(out.energy_fj > 0.0, "scrub writes cost real energy");
    assert!(out.junction_pulses as usize >= flips, "wear is charged");
    assert_eq!(
        aged.xbar.write_pulses,
        wear_before + out.junction_pulses,
        "scrub pulses land on the array's wear counter"
    );

    // Bit-identity: codes, conductances, and the computed answers.
    assert_eq!(aged.xbar.read_codes(), golden);
    assert_eq!(aged.xbar.conductances(), pristine.xbar.conductances());
    let mut rng = Rng::new(97);
    for _ in 0..4 {
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let a = aged.mvm_batch(std::slice::from_ref(&x));
        let p = pristine.mvm_batch(std::slice::from_ref(&x));
        assert_eq!(a.engine_used(), p.engine_used());
        assert_eq!(a.engine_used(), EngineUsed::Quantized, "gate restored");
        let (ra, rp) = (a.result(0), p.result(0));
        assert_eq!(ra.y_mac, rp.y_mac);
        assert_eq!(ra.t_out_ns, rp.t_out_ns);
        assert_eq!(ra.v_charge, rp.v_charge);
        assert_eq!(ra.energy, rp.energy);
    }
}

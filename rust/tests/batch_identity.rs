//! Batched MVM engine (DESIGN.md S16) — cross-level integration.
//!
//! The per-level bit-identity proofs live next to their modules
//! (`macro_model::cim_macro`, `macro_model`, `fabric::chip`,
//! `fabric::executor`, `coordinator::server`, `rust/tests/fabric_e2e.rs`);
//! this file adds a mixed-sparsity soak across batch sizes and records a
//! fast-mode perf point into `BENCH_hotpath.json` so the machine-readable
//! trajectory exists even on tier-1-only runs (`ci.sh` refreshes the file
//! under the release profile, which is where the batch-vs-serial claim is
//! measured).

use spikemram::benchlib::{black_box, Harness};
use spikemram::config::{MacroConfig, MvmEngine};
use spikemram::macro_model::{CimMacro, EngineUsed, MvmBatch};
use spikemram::testkit::bench_record_dir as record_dir_for;
use spikemram::util::rng::Rng;

fn programmed(seed: u64) -> CimMacro {
    let cfg = MacroConfig::default();
    let mut m = CimMacro::new(cfg.clone());
    let mut rng = Rng::new(seed);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    m.program(&codes);
    m
}

fn mixed_inputs(seed: u64, n: usize) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            // Cycle dense → half → 1/16-sparse → all-zero.
            let density = [1.0, 0.5, 1.0 / 16.0, 0.0][i % 4];
            (0..128)
                .map(|_| {
                    if rng.f64() < density {
                        1 + rng.below(255) as u32
                    } else {
                        0
                    }
                })
                .collect()
        })
        .collect()
}

#[test]
fn mixed_sparsity_soak_across_batch_sizes() {
    let xs = mixed_inputs(4242, 24);
    let mut serial = programmed(1717);
    let want: Vec<_> = xs.iter().map(|x| serial.mvm(x)).collect();

    for batch in [1usize, 3, 8, 24] {
        let mut m = programmed(1717);
        let mut ledger = MvmBatch::default();
        let mut done = 0usize;
        while done < xs.len() {
            let hi = (done + batch).min(xs.len());
            m.mvm_batch_into(&xs[done..hi], &mut ledger);
            for b in 0..ledger.len() {
                let w = &want[done + b];
                assert_eq!(
                    ledger.y_mac(b),
                    w.y_mac.as_slice(),
                    "batch {batch}, item {}",
                    done + b
                );
                assert_eq!(ledger.t_out_ns(b), w.t_out_ns.as_slice());
                assert_eq!(ledger.latency_ns(b), w.latency_ns);
                assert_eq!(ledger.events(b), w.events);
                assert_eq!(*ledger.energy(b), w.energy);
            }
            done = hi;
        }
    }
}

/// Random inputs at `density`, with `n` items.
fn density_inputs(rng: &mut Rng, density: f64, n: usize) -> Vec<Vec<u32>> {
    (0..n)
        .map(|_| {
            (0..128)
                .map(|_| {
                    if rng.f64() < density {
                        1 + rng.below(255) as u32
                    } else {
                        0
                    }
                })
                .collect()
        })
        .collect()
}

#[test]
fn property_event_list_bitwise_equals_dense_across_densities() {
    // The S17 bit-identity property: for random batches mixing every
    // density — all-zero and all-dense items in the SAME batch — the
    // event-list engine's full ledger is bitwise equal to the dense
    // stream's, at every batch size.
    let mut rng = Rng::new(90210);
    for trial in 0..6u64 {
        let mut xs: Vec<Vec<u32>> = Vec::new();
        xs.push(vec![0u32; 128]); // all-zero item
        xs.push(vec![255u32; 128]); // saturated all-dense item
        for density in [0.01, 0.1, 0.33, 0.5, 0.9, 1.0] {
            xs.extend(density_inputs(&mut rng, density, 2));
        }
        for batch in [1usize, 5, xs.len()] {
            let mut dense = programmed(3000 + trial);
            let mut evlist = programmed(3000 + trial);
            dense.set_engine(MvmEngine::Dense);
            evlist.set_engine(MvmEngine::EventList);
            let mut dl = MvmBatch::default();
            let mut el = MvmBatch::default();
            let mut lo = 0usize;
            while lo < xs.len() {
                let hi = (lo + batch).min(xs.len());
                dense.mvm_batch_into(&xs[lo..hi], &mut dl);
                evlist.mvm_batch_into(&xs[lo..hi], &mut el);
                assert_eq!(dl.engine_used(), EngineUsed::Dense);
                assert_eq!(el.engine_used(), EngineUsed::EventList);
                for b in 0..dl.len() {
                    assert_eq!(
                        el.y_mac(b),
                        dl.y_mac(b),
                        "trial {trial} batch {batch} item {}",
                        lo + b
                    );
                    assert_eq!(el.t_out_ns(b), dl.t_out_ns(b));
                    assert_eq!(el.v_charge(b), dl.v_charge(b));
                    assert_eq!(el.latency_ns(b), dl.latency_ns(b));
                    assert_eq!(el.events(b), dl.events(b));
                    assert_eq!(*el.energy(b), *dl.energy(b));
                    assert_eq!(el.active_rows(b), dl.active_rows(b));
                }
                lo = hi;
            }
        }
    }
}

#[test]
fn property_quantized_equals_integer_oracle_every_alphabet() {
    // The S17 exactness property: for every code-alphabet size (1..=4
    // distinct programmed levels) and random densities, the quantized
    // engine equals `ideal_mvm_quantized` BITWISE — serial and batched.
    let cfg = MacroConfig::default();
    let mut rng = Rng::new(60606);
    for alphabet in 1u8..=4 {
        let mut m = CimMacro::new(cfg.clone());
        m.set_engine(MvmEngine::Quantized);
        let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
            .map(|_| rng.below(alphabet as u64) as u8)
            .collect();
        m.program(&codes);
        let mut xs: Vec<Vec<u32>> = vec![vec![0u32; 128], vec![255u32; 128]];
        for density in [0.02, 0.25, 0.75, 1.0] {
            xs.extend(density_inputs(&mut rng, density, 2));
        }
        let oracle: Vec<Vec<f64>> =
            xs.iter().map(|x| m.ideal_mvm_quantized(x)).collect();
        // Serial.
        for (x, want) in xs.iter().zip(&oracle) {
            assert_eq!(
                &m.mvm(x).y_mac,
                want,
                "alphabet {alphabet} serial"
            );
        }
        // Batched.
        let ledger = m.mvm_batch(&xs);
        assert_eq!(ledger.engine_used(), EngineUsed::Quantized);
        for (b, want) in oracle.iter().enumerate() {
            assert_eq!(
                ledger.y_mac(b),
                want.as_slice(),
                "alphabet {alphabet} batched item {b}"
            );
        }
    }
}

#[test]
fn hotpath_bench_json_records_batch_sweep() {
    // Real (fast-mode) measurements of the same cases benches/hotpath.rs
    // times, written through the same Harness::finish() path. The JSON's
    // "profile" field distinguishes this record from the release run.
    std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
    let out_dir = record_dir_for("hotpath");
    let mut m = programmed(55);
    // The trajectory rows measure the PR-3 dense engine (S17 note in
    // benches/hotpath.rs).
    m.set_engine(MvmEngine::Dense);
    let mut rng = Rng::new(56);
    let xs: Vec<Vec<u32>> = (0..64)
        .map(|_| (0..128).map(|_| 1 + rng.below(255) as u32).collect())
        .collect();

    let mut h = Harness::new("hotpath");
    h.bench_function("macro_mvm_dense", |b| {
        b.iter(|| m.mvm(black_box(&xs[0])).t_out_ns[0])
    });
    h.bench_function_n("macro_mvm_serial_dense_x8", 8, |b| {
        b.iter(|| {
            let mut acc = 0.0f64;
            for x in &xs[..8] {
                acc += m.mvm(black_box(x)).t_out_ns[0];
            }
            acc
        })
    });
    let mut ledger = MvmBatch::default();
    for batch in [1usize, 8, 64] {
        h.bench_function_n(
            &format!("macro_mvm_batch{batch}_dense"),
            batch as u64,
            |b| {
                b.iter(|| {
                    m.mvm_batch_into(black_box(&xs[..batch]), &mut ledger);
                    ledger.y_mac(batch - 1)[0]
                })
            },
        );
    }
    let path = h.finish_to(&out_dir);

    let doc = spikemram::util::json::parse(
        &std::fs::read_to_string(&path).expect("BENCH_hotpath.json written"),
    )
    .expect("valid JSON");
    assert_eq!(doc.get("group").unwrap().as_str(), Some("hotpath"));
    let benches = doc.get("benches").unwrap();
    let per_op = |name: &str| -> f64 {
        benches
            .get(name)
            .unwrap_or_else(|| panic!("bench {name} recorded"))
            .get("per_op_median_ns")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    let serial = per_op("macro_mvm_serial_dense_x8");
    let batch8 = per_op("macro_mvm_batch8_dense");
    assert!(serial > 0.0 && batch8 > 0.0);
    // No timing-ratio assertion here: wall-clock claims are only made
    // under the release profile (ci.sh hotpath smoke, EXPERIMENTS.md
    // §Perf); this test pins the record's existence and shape.
}

#[test]
fn sparsity_bench_json_recorded_on_tier1() {
    // A fast-mode BENCH_sparsity.json through the same Harness::finish()
    // path as benches/sparsity.rs, so the sparsity trajectory exists on
    // tier-1-only runs too (ci.sh refreshes the release record). Shape
    // only — timing claims live in EXPERIMENTS.md §Perf and are
    // release-profile.
    std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
    let out_dir = record_dir_for("sparsity");
    let cfg = MacroConfig::default();
    let mut m = programmed(66);
    let mut rng = Rng::new(67);
    let mut h = Harness::new("sparsity");
    let mut ledger = MvmBatch::default();
    for (dname, density) in [("d010", 0.1), ("d100", 1.0)] {
        let flat: Vec<u32> = (0..cfg.rows)
            .map(|_| {
                if rng.f64() < density {
                    1 + rng.below(255) as u32
                } else {
                    0
                }
            })
            .collect();
        for (ename, engine) in [
            ("dense", MvmEngine::Dense),
            ("event_list", MvmEngine::EventList),
            ("quantized", MvmEngine::Quantized),
        ] {
            m.set_engine(engine);
            h.bench_function_n(&format!("mvm_{dname}_b1_{ename}"), 1, |b| {
                b.iter(|| {
                    m.mvm_batch_strided_into(
                        black_box(&flat),
                        cfg.rows,
                        &mut ledger,
                    );
                    ledger.total_active_rows()
                })
            });
        }
    }
    let path = h.finish_to(&out_dir);
    let doc = spikemram::util::json::parse(
        &std::fs::read_to_string(&path).expect("BENCH_sparsity.json written"),
    )
    .expect("valid JSON");
    assert_eq!(doc.get("group").unwrap().as_str(), Some("sparsity"));
    let benches = doc.get("benches").unwrap();
    for name in [
        "mvm_d010_b1_dense",
        "mvm_d010_b1_event_list",
        "mvm_d100_b1_quantized",
    ] {
        assert!(
            benches
                .get(name)
                .and_then(|b| b.get("per_op_median_ns"))
                .and_then(|v| v.as_f64())
                .is_some_and(|v| v > 0.0),
            "row {name} missing"
        );
    }
}

//! Batched MVM engine (DESIGN.md S16) — cross-level integration.
//!
//! The per-level bit-identity proofs live next to their modules
//! (`macro_model::cim_macro`, `macro_model`, `fabric::chip`,
//! `fabric::executor`, `coordinator::server`, `rust/tests/fabric_e2e.rs`);
//! this file adds a mixed-sparsity soak across batch sizes and records a
//! fast-mode perf point into `BENCH_hotpath.json` so the machine-readable
//! trajectory exists even on tier-1-only runs (`ci.sh` refreshes the file
//! under the release profile, which is where the batch-vs-serial claim is
//! measured).

use spikemram::benchlib::{black_box, Harness};
use spikemram::config::MacroConfig;
use spikemram::macro_model::{CimMacro, MvmBatch};
use spikemram::util::rng::Rng;

fn programmed(seed: u64) -> CimMacro {
    let cfg = MacroConfig::default();
    let mut m = CimMacro::new(cfg.clone());
    let mut rng = Rng::new(seed);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    m.program(&codes);
    m
}

fn mixed_inputs(seed: u64, n: usize) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            // Cycle dense → half → 1/16-sparse → all-zero.
            let density = [1.0, 0.5, 1.0 / 16.0, 0.0][i % 4];
            (0..128)
                .map(|_| {
                    if rng.f64() < density {
                        1 + rng.below(255) as u32
                    } else {
                        0
                    }
                })
                .collect()
        })
        .collect()
}

#[test]
fn mixed_sparsity_soak_across_batch_sizes() {
    let xs = mixed_inputs(4242, 24);
    let mut serial = programmed(1717);
    let want: Vec<_> = xs.iter().map(|x| serial.mvm(x)).collect();

    for batch in [1usize, 3, 8, 24] {
        let mut m = programmed(1717);
        let mut ledger = MvmBatch::default();
        let mut done = 0usize;
        while done < xs.len() {
            let hi = (done + batch).min(xs.len());
            m.mvm_batch_into(&xs[done..hi], &mut ledger);
            for b in 0..ledger.len() {
                let w = &want[done + b];
                assert_eq!(
                    ledger.y_mac(b),
                    w.y_mac.as_slice(),
                    "batch {batch}, item {}",
                    done + b
                );
                assert_eq!(ledger.t_out_ns(b), w.t_out_ns.as_slice());
                assert_eq!(ledger.latency_ns(b), w.latency_ns);
                assert_eq!(ledger.events(b), w.events);
                assert_eq!(*ledger.energy(b), w.energy);
            }
            done = hi;
        }
    }
}

#[test]
fn hotpath_bench_json_records_batch_sweep() {
    // Real (fast-mode) measurements of the same cases benches/hotpath.rs
    // times, written through the same Harness::finish() path. The JSON's
    // "profile" field distinguishes this record from the release run —
    // and an existing release-profile record (from the ci.sh hotpath
    // smoke) is never clobbered with this binary's numbers: the test
    // then validates the writer against a scratch directory instead.
    std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
    // Probe the directory the release bench run (ci.sh) writes into.
    let record_dir = std::path::PathBuf::from(
        std::env::var("SPIKEMRAM_BENCH_DIR").unwrap_or_else(|_| ".".into()),
    );
    let keep_release =
        std::fs::read_to_string(record_dir.join("BENCH_hotpath.json"))
            .ok()
            .and_then(|s| spikemram::util::json::parse(&s).ok())
            .and_then(|d| {
                d.get("profile").and_then(|p| p.as_str().map(String::from))
            })
            .is_some_and(|p| p == "release");
    let out_dir = if keep_release {
        let dir = std::env::temp_dir().join("spikemram_hotpath_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir
    } else {
        record_dir
    };
    let mut m = programmed(55);
    let mut rng = Rng::new(56);
    let xs: Vec<Vec<u32>> = (0..64)
        .map(|_| (0..128).map(|_| 1 + rng.below(255) as u32).collect())
        .collect();

    let mut h = Harness::new("hotpath");
    h.bench_function("macro_mvm_dense", |b| {
        b.iter(|| m.mvm(black_box(&xs[0])).t_out_ns[0])
    });
    h.bench_function_n("macro_mvm_serial_dense_x8", 8, |b| {
        b.iter(|| {
            let mut acc = 0.0f64;
            for x in &xs[..8] {
                acc += m.mvm(black_box(x)).t_out_ns[0];
            }
            acc
        })
    });
    let mut ledger = MvmBatch::default();
    for batch in [1usize, 8, 64] {
        h.bench_function_n(
            &format!("macro_mvm_batch{batch}_dense"),
            batch as u64,
            |b| {
                b.iter(|| {
                    m.mvm_batch_into(black_box(&xs[..batch]), &mut ledger);
                    ledger.y_mac(batch - 1)[0]
                })
            },
        );
    }
    let path = h.finish_to(&out_dir);

    let doc = spikemram::util::json::parse(
        &std::fs::read_to_string(&path).expect("BENCH_hotpath.json written"),
    )
    .expect("valid JSON");
    assert_eq!(doc.get("group").unwrap().as_str(), Some("hotpath"));
    let benches = doc.get("benches").unwrap();
    let per_op = |name: &str| -> f64 {
        benches
            .get(name)
            .unwrap_or_else(|| panic!("bench {name} recorded"))
            .get("per_op_median_ns")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    let serial = per_op("macro_mvm_serial_dense_x8");
    let batch8 = per_op("macro_mvm_batch8_dense");
    assert!(serial > 0.0 && batch8 > 0.0);
    // No timing-ratio assertion here: wall-clock claims are only made
    // under the release profile (ci.sh hotpath smoke, EXPERIMENTS.md
    // §Perf); this test pins the record's existence and shape.
}

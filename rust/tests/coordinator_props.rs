//! Property-based tests on coordinator + macro invariants (testkit).
//!
//! These are the invariants DESIGN.md calls out for the L3 contribution:
//! routing correctness (results independent of policy/workers), batching
//! conservation (no request lost or duplicated), tiling linearity, and
//! the macro's Eq. 2 exactness over the whole input/weight space.

use spikemram::config::MacroConfig;
use spikemram::coordinator::{
    Batcher, Policy, Request, Scheduler, TileOp, TiledMatrix,
};
use spikemram::macro_model::CimMacro;
use spikemram::testkit::{self, gen, PropConfig};
use spikemram::util::rng::Rng;

#[test]
fn prop_macro_mvm_equals_digital_oracle() {
    testkit::check(
        PropConfig { cases: 24, seed: 0xA },
        "mvm == oracle",
        |rng| {
            let density = rng.uniform(0.05, 1.0);
            (
                gen::codes(rng, 128, 128),
                gen::sparse_input(rng, 128, density),
            )
        },
        |(codes, x)| {
            let mut m = CimMacro::new(MacroConfig::default());
            m.program(codes);
            let got = m.mvm(x).y_mac;
            let want = m.ideal_mvm(x);
            for (g, w) in got.iter().zip(&want) {
                testkit::assert_close(*g, *w, 1e-9, 1e-6)?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mvm_is_linear_in_inputs() {
    // Eq. 2: mvm(a + b) == mvm(a) + mvm(b) (within the 8-bit range).
    testkit::check(
        PropConfig { cases: 16, seed: 0xB },
        "mvm additivity",
        |rng| {
            let codes = gen::codes(rng, 128, 128);
            let a: Vec<u32> = (0..128).map(|_| rng.below(128) as u32).collect();
            let b: Vec<u32> = (0..128).map(|_| rng.below(128) as u32).collect();
            (codes, a, b)
        },
        |(codes, a, b)| {
            let mut m = CimMacro::new(MacroConfig::default());
            m.program(codes);
            let ya = m.mvm(a).y_mac;
            let yb = m.mvm(b).y_mac;
            let sum: Vec<u32> = a.iter().zip(b).map(|(x, y)| x + y).collect();
            let ys = m.mvm(&sum).y_mac;
            for c in 0..128 {
                testkit::assert_close(ys[c], ya[c] + yb[c], 1e-9, 1e-6)?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scheduler_results_independent_of_policy_and_workers() {
    testkit::check(
        PropConfig { cases: 8, seed: 0xC },
        "scheduling invariance",
        |rng| {
            let row_tiles = 1 + rng.below(2) as usize;
            let codes = gen::codes(rng, 128 * row_tiles, 128);
            let tm = TiledMatrix::new(&codes, 128 * row_tiles, 128, 128);
            let n_ops = 4 + rng.below(8) as usize;
            let ops: Vec<TileOp> = (0..n_ops)
                .map(|_| TileOp {
                    tile_idx: rng.below(tm.num_tiles() as u64) as usize,
                    x: gen::input_vec(rng, 128),
                    arrival_ns: 0.0,
                })
                .collect();
            let workers = 1 + rng.below(4) as usize;
            (tm, ops, workers)
        },
        |(tm, ops, workers)| {
            let cfg = MacroConfig::default();
            let base = Scheduler::new(&cfg, 1, Policy::RoundRobin)
                .run(tm, ops)
                .results;
            for policy in
                [Policy::RoundRobin, Policy::LeastLoaded, Policy::TileAffinity]
            {
                let r = Scheduler::new(&cfg, *workers, policy).run(tm, ops);
                if r.results != base {
                    return Err(format!(
                        "results differ under {policy:?}/{workers} workers"
                    ));
                }
                // Completion times never precede arrivals.
                for (op, done) in ops.iter().zip(&r.completions_ns) {
                    if *done < op.arrival_ns {
                        return Err("completion before arrival".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batcher_conserves_requests() {
    testkit::check(
        PropConfig { cases: 32, seed: 0xD },
        "batching conservation",
        |rng| {
            let n = 1 + rng.below(64) as usize;
            let max_batch = 1 + rng.below(16) as usize;
            let timeout = rng.uniform(1.0, 500.0);
            let arrivals: Vec<f64> = {
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.uniform(0.0, 100.0);
                        t
                    })
                    .collect()
            };
            (arrivals, max_batch, timeout)
        },
        |(arrivals, max_batch, timeout)| {
            let mut b: Batcher<u64> = Batcher::new(*max_batch, *timeout);
            let mut seen: Vec<u64> = Vec::new();
            for (i, &t) in arrivals.iter().enumerate() {
                // Poll timeouts before each arrival (virtual time moves).
                while let Some(batch) = b.poll(t) {
                    seen.extend(batch.requests.iter().map(|r| r.id));
                }
                if let Some(batch) = b.push(
                    Request {
                        id: i as u64,
                        payload: i as u64,
                        arrived_ns: t,
                    },
                    t,
                ) {
                    if batch.requests.len() > *max_batch {
                        return Err("batch exceeded max size".into());
                    }
                    seen.extend(batch.requests.iter().map(|r| r.id));
                }
            }
            let t_end = arrivals.last().unwrap() + timeout * 2.0;
            while let Some(batch) = b.poll(t_end) {
                seen.extend(batch.requests.iter().map(|r| r.id));
            }
            if let Some(batch) = b.flush(t_end) {
                seen.extend(batch.requests.iter().map(|r| r.id));
            }
            seen.sort_unstable();
            let want: Vec<u64> = (0..arrivals.len() as u64).collect();
            if seen != want {
                return Err(format!("lost/dup requests: {seen:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tiled_mvm_equals_dense_for_ragged_shapes() {
    testkit::check(
        PropConfig { cases: 12, seed: 0xE },
        "ragged tiling correctness",
        |rng| {
            let k = 1 + rng.below(300) as usize;
            let n = 1 + rng.below(200) as usize;
            let codes = gen::codes(rng, k, n);
            let x = gen::input_vec(rng, k);
            (k, n, codes, x)
        },
        |(k, n, codes, x)| {
            let levels = MacroConfig::default().level_map.levels();
            let mut want = vec![0.0f64; *n];
            for r in 0..*k {
                for c in 0..*n {
                    want[c] +=
                        x[r] as f64 * levels[codes[r * n + c] as usize];
                }
            }
            let tm = TiledMatrix::new(codes, *k, *n, 128);
            let xp = tm.split_input(x);
            let mut partials = Vec::new();
            for ti in 0..tm.row_tiles {
                let mut row = Vec::new();
                for tj in 0..tm.col_tiles {
                    let tc = tm.tile_codes(ti, tj);
                    let mut part = vec![0.0f64; 128];
                    for r in 0..128 {
                        let xv = xp[ti][r] as f64;
                        if xv == 0.0 {
                            continue;
                        }
                        for (c, p) in part.iter_mut().enumerate() {
                            *p += xv * levels[tc[r * 128 + c] as usize];
                        }
                    }
                    row.push(part);
                }
                partials.push(row);
            }
            let got = tm.accumulate(&partials);
            for (g, w) in got.iter().zip(&want) {
                testkit::assert_close(*g, *w, 1e-9, 1e-6)?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_event_count_tracks_active_rows() {
    // Event-driven invariant: events = 2·(active rows) + cols.
    testkit::check(
        PropConfig { cases: 16, seed: 0xF },
        "event sparsity",
        |rng| {
            let density = rng.uniform(0.0, 1.0);
            gen::sparse_input(rng, 128, density)
        },
        |x| {
            let mut rng = Rng::new(7);
            let codes = gen::codes(&mut rng, 128, 128);
            let mut m = CimMacro::new(MacroConfig::default());
            m.program(&codes);
            let active = x.iter().filter(|&&v| v > 0).count() as u64;
            let r = m.mvm(x);
            let want = if active == 0 { 128 } else { 2 * active + 128 };
            if r.events != want {
                return Err(format!("events {} != {want}", r.events));
            }
            Ok(())
        },
    );
}

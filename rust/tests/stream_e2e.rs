//! Temporal streaming runtime (DESIGN.md S18) — cross-level acceptance.
//!
//! The per-level proofs live next to their modules (`cim_macro` unit
//! tests, `fabric::chip`, `stream::{snn,exec,serve}`); this file pins
//! the S18 acceptance bars end-to-end:
//!
//! * the binary-spike fast path is bitwise equal to the dense engine on
//!   0/1 inputs across densities (macro level, forced engines);
//! * pipelined streaming execution is bitwise identical to the serial
//!   timestep loop — membrane potentials, spike trains, accumulated
//!   MACs (membranes are a deterministic function of the per-step
//!   y_mac, and the spike trains pin every intermediate), energy
//!   tallies — at fabric and server levels;
//! * a fast-mode `BENCH_stream.json` lands through `Harness::finish()`
//!   so the stream perf trajectory exists on tier-1-only runs (ci.sh
//!   refreshes the release record).

use spikemram::benchlib::{black_box, Harness};
use spikemram::config::{
    FabricConfig, LevelMap, MacroConfig, MvmEngine, StreamConfig,
};
use spikemram::macro_model::CimMacro;
use spikemram::snn::{Dataset, Mlp};
use spikemram::stream::{
    collect_frames, FrameEncoder, PoissonStream, SpikingMlp, StreamServer,
    StreamServerConfig, StreamSpec, TemporalCode,
};
use spikemram::util::rng::Rng;

fn programmed(seed: u64, engine: MvmEngine) -> CimMacro {
    let cfg = MacroConfig {
        engine,
        ..MacroConfig::default()
    };
    let mut m = CimMacro::new(cfg);
    let mut rng = Rng::new(seed);
    let codes: Vec<u8> = (0..128 * 128).map(|_| rng.below(4) as u8).collect();
    m.program(&codes);
    m
}

#[test]
fn binary_spike_fast_path_bitwise_equals_dense_engine() {
    // Acceptance bar: the event-list fast path on 0/1 inputs equals the
    // dense engine bitwise, across densities — including the empty and
    // the saturated frame, interleaved in one stream.
    let mut dense = programmed(11, MvmEngine::Dense);
    let mut evlist = programmed(11, MvmEngine::EventList);
    let mut rng = Rng::new(12);
    for density in [0.0, 0.01, 0.1, 0.5, 0.9, 1.0] {
        let x: Vec<u32> = (0..128)
            .map(|_| if rng.f64() < density { 1 } else { 0 })
            .collect();
        let ev: Vec<u32> = x
            .iter()
            .enumerate()
            .filter(|(_, &v)| v > 0)
            .map(|(r, _)| r as u32)
            .collect();
        let want = dense.mvm(&x);
        let got = evlist.mvm_events(&ev);
        assert_eq!(got.y_mac, want.y_mac, "density {density}");
        assert_eq!(got.t_out_ns, want.t_out_ns);
        assert_eq!(got.v_charge, want.v_charge);
        assert_eq!(got.latency_ns, want.latency_ns);
        assert_eq!(got.events, want.events);
        assert_eq!(got.energy, want.energy);
    }
}

fn deployed(seed: u64) -> (SpikingMlp, Dataset) {
    let calib = Dataset::generate(40, seed);
    let model = Mlp::new(seed ^ 0x7);
    let mlp = SpikingMlp::from_float(
        &model,
        &calib,
        &MacroConfig::default(),
        FabricConfig::square(2),
        LevelMap::DeviceTrue,
        &StreamConfig::default(),
    )
    .unwrap();
    (mlp, calib)
}

#[test]
fn pipelined_stream_bitwise_equals_serial_timestep_loop() {
    // Acceptance bar: pipelined == serial bitwise — membranes, spike
    // trains, energy tallies — over encoded digits AND DVS-style
    // Poisson traffic, at several T.
    let (mut mlp, data) = deployed(21);
    for (i, t) in [1usize, 4, 16].into_iter().enumerate() {
        let enc = FrameEncoder::new(TemporalCode::Rate, t, 255);
        let frames = enc.encode_frames(&data.features_u8(i));
        let serial = mlp.run(&frames);
        let piped = mlp.run_pipelined(&frames);
        assert_eq!(piped.out_v, serial.out_v, "membranes T={t}");
        assert_eq!(piped.trains, serial.trains, "spike trains T={t}");
        assert_eq!(piped.label, serial.label);
        assert_eq!(piped.stats.energy, serial.stats.energy, "energy T={t}");
        assert_eq!(piped.stats.latency_ns, serial.stats.latency_ns);
        assert_eq!(piped.stats.active_rows, serial.stats.active_rows);
        assert_eq!(piped.stats.macs, serial.stats.macs);
        assert_eq!(piped.stats.noc_packets, serial.stats.noc_packets);
        assert_eq!(piped.stats.noc_hops, serial.stats.noc_hops);
        assert_eq!(piped.stats.layer_spikes, serial.stats.layer_spikes);
    }
    // DVS-style traffic, TTFS-encoded statics: same contract.
    let mut dvs = PoissonStream::uniform(256, 10, 0.12, 23);
    let frames = collect_frames(&mut dvs);
    let serial = mlp.run(&frames);
    let piped = mlp.run_pipelined(&frames);
    assert_eq!(piped.out_v, serial.out_v);
    assert_eq!(piped.trains, serial.trains);
    assert_eq!(piped.stats.energy, serial.stats.energy);
    let enc = FrameEncoder::new(TemporalCode::Ttfs, 8, 255);
    let frames = enc.encode_frames(&data.features_u8(3));
    let serial = mlp.run(&frames);
    let piped = mlp.run_pipelined(&frames);
    assert_eq!(piped.out_v, serial.out_v);
    assert_eq!(piped.trains, serial.trains);
}

#[test]
fn stream_server_sessions_bitwise_equal_serial_runs() {
    // Acceptance bar at the server level: interleaved sessions with
    // swapped-out membrane state reproduce the serial loop bitwise.
    let spec = StreamSpec {
        model: Mlp::new(31),
        calib: Dataset::generate(24, 32),
        mcfg: MacroConfig::default(),
        fabric: FabricConfig::square(2),
        level_map: LevelMap::DeviceTrue,
        stream: StreamConfig::default(),
    };
    let mut serial = spec.build().unwrap();
    let server = StreamServer::start(
        spec,
        StreamServerConfig {
            workers: 2,
            ..StreamServerConfig::default()
        },
    )
    .unwrap();
    let data = Dataset::generate(4, 33);
    let enc = FrameEncoder::new(TemporalCode::Rate, 6, 255);
    let frames: Vec<Vec<Vec<u32>>> = (0..4)
        .map(|i| enc.encode_frames(&data.features_u8(i)))
        .collect();
    let ids: Vec<u64> = (0..4).map(|_| server.open_session()).collect();
    for t in 0..6 {
        for (s, &id) in ids.iter().enumerate() {
            server.frame(id, frames[s][t].clone());
        }
    }
    for (s, &id) in ids.iter().enumerate() {
        let want = serial.run(&frames[s]);
        let got = server.finish(id);
        assert_eq!(got.out_v, want.out_v, "session {s} membranes");
        assert_eq!(got.label, want.label);
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 24);
    assert!(snap.energy_fj > 0.0);
    assert!(snap.input_density() > 0.0 && snap.input_density() < 1.0);
    server.shutdown();
}

#[test]
fn background_scrubber_interleaves_with_serving_without_races() {
    // S19 acceptance bar: a background scrubber sharing the worker
    // FIFOs with live sticky sessions must (a) never deadlock on the
    // shared pool, (b) repair at least as many cells as drift flipped
    // once quiesced, and (c) leave every session's outputs bitwise
    // equal to a serialized scrub-then-serve reference — here the
    // pristine serial model, since a completed drift-only scrub
    // restores the deployment bit-for-bit.
    use spikemram::device::{FaultPlan, RetentionParams};
    use std::time::Duration;

    let spec = StreamSpec {
        model: Mlp::new(51),
        calib: Dataset::generate(24, 52),
        mcfg: MacroConfig::default(),
        fabric: FabricConfig::square(2),
        level_map: LevelMap::DeviceTrue,
        stream: StreamConfig::default(),
    };
    let mut serial = spec.build().unwrap();
    let plan = FaultPlan::drift_only(RetentionParams::stress(), 53);
    let server = StreamServer::start(
        spec,
        StreamServerConfig {
            workers: 2,
            faults: Some(plan),
            ..StreamServerConfig::default()
        },
    )
    .unwrap();

    // Inject one round of drift, then repair it synchronously so the
    // arrays are bit-pristine before traffic starts.
    let flips = server.drift(plan.retention.tau_ret_ns());
    assert!(flips > 0, "stress corner must flip cells at t=τ");
    let repaired = server.scrub_now();
    assert_eq!(repaired.repaired as u64, flips, "full repair");

    // Background scrubber ticking fast: every tick enqueues scrub jobs
    // into the same FIFOs the frames flow through, so scrubs and
    // frames genuinely interleave at the workers while we stream. The
    // scrubber is owned by the server since S21.
    server.start_scrubber(Duration::from_millis(1));

    let data = Dataset::generate(4, 54);
    let enc = FrameEncoder::new(TemporalCode::Rate, 6, 255);
    let frames: Vec<Vec<Vec<u32>>> = (0..4)
        .map(|i| enc.encode_frames(&data.features_u8(i)))
        .collect();
    let ids: Vec<u64> = (0..4).map(|_| server.open_session()).collect();
    for t in 0..6 {
        for (s, &id) in ids.iter().enumerate() {
            server.frame(id, frames[s][t].clone());
        }
    }
    for (s, &id) in ids.iter().enumerate() {
        let want = serial.run(&frames[s]);
        let got = server.finish(id);
        assert_eq!(got.out_v, want.out_v, "session {s} membranes");
        assert_eq!(got.label, want.label);
    }

    // Quiesce: stop_scrubber() returns only after the tick loop exited.
    server.stop_scrubber();
    server.scrub_now(); // drain-barrier: all queued scrubs are done
    let snap = server.metrics.snapshot();
    assert!(snap.flips_repaired >= snap.flips_injected, "{snap:?}");
    assert_eq!(snap.flips_injected, flips);
    assert!(snap.scrubs >= 3, "sync + per-tick scrubs, got {}", snap.scrubs);
    assert!(snap.scrub_energy_fj > 0.0, "scrub writes charged");
    assert!(snap.scrub_duty_cycle() > 0.0);
    server.shutdown();
}

#[test]
fn stream_bench_json_recorded_on_tier1() {
    // A fast-mode BENCH_stream.json through the same Harness::finish()
    // path as benches/stream.rs, so the stream perf trajectory exists
    // on tier-1-only runs (ci.sh refreshes the release record and fails
    // when the file is missing). Shape only — timing claims live in
    // EXPERIMENTS.md §Perf and are release-profile.
    std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
    let out_dir = spikemram::testkit::bench_record_dir("stream");
    let (mut mlp, _) = deployed(41);
    let mut h = Harness::new("stream");
    for (t, density) in [(1usize, 0.5), (4, 0.05)] {
        let mut src =
            PoissonStream::uniform(256, t, density, 42 + t as u64);
        let frames = collect_frames(&mut src);
        h.bench_function_n(
            &format!("stream_t{t}_d{:03}", (density * 100.0) as u32),
            t as u64,
            |b| b.iter(|| mlp.run(black_box(&frames)).stats.active_rows),
        );
    }
    let path = h.finish_to(&out_dir);
    let doc = spikemram::util::json::parse(
        &std::fs::read_to_string(&path).expect("BENCH_stream.json written"),
    )
    .expect("valid JSON");
    assert_eq!(doc.get("group").unwrap().as_str(), Some("stream"));
    let benches = doc.get("benches").unwrap();
    for name in ["stream_t1_d050", "stream_t4_d005"] {
        assert!(
            benches
                .get(name)
                .and_then(|b| b.get("per_op_median_ns"))
                .and_then(|v| v.as_f64())
                .is_some_and(|v| v > 0.0),
            "row {name} missing"
        );
    }
}

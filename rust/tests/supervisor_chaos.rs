//! Supervised serving control plane (DESIGN.md S21) — chaos soak.
//!
//! Injects worker panics mid-frame through the deterministic
//! [`ChaosPlan`] hook and pins the S21 acceptance bars end-to-end:
//!
//! * **Panic isolation + bitwise recovery** — with a generous restart
//!   budget, every frame is eventually served and every session's
//!   outputs are bitwise equal to the serial single-threaded reference:
//!   session membranes survive the crash (pre-frame snapshot), and the
//!   restarted worker resumes from a fresh pristine replica built from
//!   the golden spec, never the poisoned die.
//! * **Accounting closure under random chaos** — every admitted frame
//!   resolves to exactly one outcome: served + shed == submitted, no
//!   frame both shed and served, none silently lost; the server's own
//!   metrics agree with the client-side tallies.
//! * **Graceful degradation** — once the restart budget is exhausted
//!   the worker degrades: later frames are shed with
//!   [`ShedReason::RestartBudget`] (never a hang, never a crash of the
//!   caller), sessions still drain through `finish`, and the degraded
//!   gauge is raised.

use std::time::Duration;

use spikemram::config::{
    FabricConfig, LevelMap, MacroConfig, StreamConfig,
};
use spikemram::coordinator::{ChaosPlan, RestartPolicy, ShedReason};
use spikemram::snn::{Dataset, Mlp};
use spikemram::stream::{
    FrameEncoder, FrameOutcome, StreamServer, StreamServerConfig, StreamSpec,
    TemporalCode,
};

fn spec(seed: u64) -> StreamSpec {
    StreamSpec {
        model: Mlp::new(seed),
        calib: Dataset::generate(24, seed ^ 0x9),
        mcfg: MacroConfig::default(),
        fabric: FabricConfig::square(2),
        level_map: LevelMap::DeviceTrue,
        stream: StreamConfig::default(),
    }
}

/// A cheap restart loop: the "die swap" is a rebuild, so keep the
/// backoff at the floor and the budget effectively unlimited.
fn generous() -> RestartPolicy {
    RestartPolicy {
        max_restarts: 100,
        backoff: Duration::from_millis(1),
        backoff_max: Duration::from_millis(2),
    }
}

#[test]
fn chaos_soak_untouched_sessions_stay_bitwise_identical() {
    // 8 sticky sessions across 2 workers, deterministic panics every
    // 7th frame attempt per worker. Sessions that never saw a panic
    // AND sessions whose frames were retried across a restart must
    // both land bitwise on the serial reference — the membrane
    // snapshot plus golden-spec rebuild leaves no trace of the crash.
    let sp = spec(61);
    let mut serial = sp.build().expect("2x2 mesh holds the digit MLP");
    let server = StreamServer::start(
        sp,
        StreamServerConfig {
            workers: 2,
            chaos: Some(ChaosPlan::every(7)),
            restart: generous(),
            ..StreamServerConfig::default()
        },
    )
    .expect("server starts");

    let data = Dataset::generate(8, 62);
    let enc = FrameEncoder::new(TemporalCode::Rate, 6, 255);
    let frames: Vec<Vec<Vec<u32>>> = (0..8)
        .map(|i| enc.encode_frames(&data.features_u8(i)))
        .collect();
    let ids: Vec<u64> = (0..8).map(|_| server.open_session()).collect();
    for t in 0..6 {
        for (s, &id) in ids.iter().enumerate() {
            // Within budget, every-mode retries converge: the frame is
            // served (a shed here would panic the expect_served path).
            server.frame(id, frames[s][t].clone());
        }
    }
    for (s, &id) in ids.iter().enumerate() {
        let want = serial.run(&frames[s]);
        let got = server.finish(id);
        assert_eq!(got.out_v, want.out_v, "session {s} membranes");
        assert_eq!(got.label, want.label, "session {s} label");
    }
    let snap = server.metrics.snapshot();
    assert!(snap.worker_panics >= 2, "chaos must have fired: {snap:?}");
    assert_eq!(
        snap.worker_panics, snap.restarts,
        "every panic earned a restart within the generous budget"
    );
    assert_eq!(snap.requests, 48, "all 8x6 frames served");
    assert_eq!(snap.sheds_total(), 0, "nothing shed within budget");
    assert_eq!(snap.degraded_workers, 0);
    let rep = server.shutdown();
    assert!(rep.clean, "no in-flight frames at shutdown");
}

#[test]
fn random_chaos_resolves_every_frame_exactly_once() {
    // Probabilistic chaos (~5 % of attempts) with a modest budget:
    // some frames are served after restarts, some are shed when a
    // worker degrades. The invariant is accounting closure — exactly
    // one outcome per submitted frame, client and server tallies agree.
    let server = StreamServer::start(
        spec(71),
        StreamServerConfig {
            workers: 2,
            chaos: Some(ChaosPlan::rate(0.05, 72)),
            restart: RestartPolicy {
                max_restarts: 4,
                ..generous()
            },
            ..StreamServerConfig::default()
        },
    )
    .expect("server starts");

    let data = Dataset::generate(8, 73);
    let enc = FrameEncoder::new(TemporalCode::Rate, 25, 255);
    let frames: Vec<Vec<Vec<u32>>> = (0..8)
        .map(|i| enc.encode_frames(&data.features_u8(i)))
        .collect();
    let ids: Vec<u64> = (0..8).map(|_| server.open_session()).collect();

    let mut submitted = 0u64;
    let mut rxs = Vec::new();
    for t in 0..25 {
        for (s, &id) in ids.iter().enumerate() {
            submitted += 1;
            rxs.push(server.submit_frame(id, frames[s][t].clone()));
        }
    }
    let (mut served, mut shed) = (0u64, 0u64);
    for rx in rxs {
        // Exactly one outcome per admitted frame; a second recv would
        // block forever, a lost frame would fail the recv.
        match rx.recv().expect("every admitted frame gets an outcome") {
            FrameOutcome::Served(_) => served += 1,
            FrameOutcome::Shed { reason, .. } => {
                assert_eq!(
                    reason,
                    ShedReason::RestartBudget,
                    "no deadline, no drain: only budget sheds possible"
                );
                shed += 1;
            }
        }
    }
    assert_eq!(served + shed, submitted, "no frame lost or double-counted");
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, served, "server served tally agrees");
    assert_eq!(snap.sheds_restart, shed, "server shed tally agrees");
    assert!(snap.worker_panics >= 1, "rate chaos fired: {snap:?}");
    assert!(
        snap.restarts <= snap.worker_panics,
        "restarts only ever follow panics"
    );
    // Sessions always drain, even off degraded workers.
    for &id in &ids {
        let r = server.finish(id);
        assert!(!r.out_v.is_empty());
    }
    server.shutdown();
}

#[test]
fn exhausted_restart_budget_degrades_worker_not_process() {
    // Panic every 2nd attempt with a budget of 1: the single worker
    // serves, restarts once, then degrades. From then on frames are
    // shed with RestartBudget — the caller never hangs, the process
    // never dies, and the session still finishes.
    let server = StreamServer::start(
        spec(81),
        StreamServerConfig {
            workers: 1,
            chaos: Some(ChaosPlan::every(2)),
            restart: RestartPolicy {
                max_restarts: 1,
                ..generous()
            },
            ..StreamServerConfig::default()
        },
    )
    .expect("server starts");
    let id = server.open_session();
    let (mut served, mut shed) = (0u64, 0u64);
    for _ in 0..10 {
        match server
            .submit_frame(id, vec![0, 3, 5])
            .recv()
            .expect("outcome")
        {
            FrameOutcome::Served(_) => served += 1,
            FrameOutcome::Shed { reason, session } => {
                assert_eq!(reason, ShedReason::RestartBudget);
                assert_eq!(session, id);
                shed += 1;
            }
        }
    }
    assert!(served >= 1, "the worker served before degrading");
    assert!(shed >= 1, "the exhausted budget must shed");
    assert_eq!(served + shed, 10);
    let snap = server.metrics.snapshot();
    assert_eq!(snap.degraded_workers, 1, "degrade gauge raised: {snap:?}");
    assert_eq!(snap.restarts, 1, "budget allowed exactly one restart");
    assert!(snap.worker_panics >= 2, "panic before and after the restart");
    assert_eq!(snap.sheds_restart, shed);
    // Drain-only mode: the session's state is still reachable.
    let r = server.finish(id);
    assert!(!r.out_v.is_empty());
    server.shutdown();
}

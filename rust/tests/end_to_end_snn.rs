//! End-to-end pipeline test (experiment E9, small scale): generate data,
//! train float, quantize to conductance codes, run inference through the
//! full behavioral macro stack, and check accuracy + energy accounting.
//! The full-size run lives in `examples/snn_inference.rs`.

use spikemram::config::{LevelMap, MacroConfig};
use spikemram::snn;

#[test]
fn train_quantize_deploy_pipeline() {
    let train_data = snn::Dataset::generate(200, 3001);
    let test_data = snn::Dataset::generate(80, 3002);
    let (model, train_acc) = snn::train(&train_data, 5, 9);
    assert!(train_acc > 0.85, "float train acc {train_acc}");

    let cfg = MacroConfig::default();
    let mut mm = snn::MacroMlp::from_float(
        &model,
        &train_data,
        &cfg,
        LevelMap::DeviceTrue,
    );
    let (acc, stats) = mm.evaluate(&test_data);
    let float_acc = snn::accuracy(&model, &test_data);
    assert!(
        acc > float_acc - 0.2,
        "macro acc {acc} too far below float {float_acc}"
    );

    // Energy accounting must be self-consistent.
    let n = test_data.len() as f64;
    let per_inf_pj = stats.energy.total_pj() / n;
    // 5 macro MVMs per inference (2 + 1 + 1 tiles… layer1 has 2 row
    // tiles ×1 col tile, layers 2–3 one tile each) ≈ 4 × ~134 pJ.
    assert!(
        per_inf_pj > 100.0 && per_inf_pj < 2000.0,
        "per-inference energy {per_inf_pj} pJ"
    );
    assert!(stats.latency_ns / n > 100.0); // 3 dependent layers
    let tops_w = spikemram::energy::tops_per_watt(
        stats.macs * 2,
        stats.energy.total_fj(),
    );
    // Efficiency on the real (sparse, low-activity) workload can exceed
    // the uniform-random headline; sanity-band only.
    assert!(
        tops_w > 50.0 && tops_w < 5000.0,
        "end-to-end {tops_w} TOPS/W"
    );
}

#[test]
fn device_true_vs_ideal_levels_ablation() {
    // DESIGN.md §7: the non-uniform device levels must not collapse
    // accuracy relative to idealized levels (the quantizer targets the
    // true levels), but ideal levels should never be *worse*.
    let train_data = snn::Dataset::generate(200, 3003);
    let test_data = snn::Dataset::generate(80, 3004);
    let (model, _) = snn::train(&train_data, 5, 11);
    let cfg = MacroConfig::default();

    let mut device = snn::MacroMlp::from_float(
        &model,
        &train_data,
        &cfg,
        LevelMap::DeviceTrue,
    );
    let (acc_device, _) = device.evaluate(&test_data);

    let ideal_cfg = MacroConfig {
        level_map: LevelMap::IdealLinear,
        ..cfg
    };
    let mut ideal = snn::MacroMlp::from_float(
        &model,
        &train_data,
        &ideal_cfg,
        LevelMap::IdealLinear,
    );
    let (acc_ideal, _) = ideal.evaluate(&test_data);

    assert!(acc_device > 0.6, "device-true acc {acc_device}");
    assert!(
        acc_ideal >= acc_device - 0.1,
        "ideal {acc_ideal} vs device {acc_device}"
    );
}

#[test]
fn nonideal_circuits_degrade_gracefully() {
    use spikemram::config::NonIdeality;
    let train_data = snn::Dataset::generate(150, 3005);
    let test_data = snn::Dataset::generate(60, 3006);
    let (model, _) = snn::train(&train_data, 5, 13);

    let ideal_cfg = MacroConfig::default();
    let mut ideal = snn::MacroMlp::from_float(
        &model,
        &train_data,
        &ideal_cfg,
        LevelMap::DeviceTrue,
    );
    let (acc_ideal, _) = ideal.evaluate(&test_data);

    let noisy_cfg = MacroConfig {
        nonideal: NonIdeality::realistic(),
        ..MacroConfig::default()
    };
    let mut noisy = snn::MacroMlp::from_float(
        &model,
        &train_data,
        &noisy_cfg,
        LevelMap::DeviceTrue,
    );
    let (acc_noisy, _) = noisy.evaluate(&test_data);

    // Realistic non-idealities cost a few points, not a collapse.
    assert!(
        acc_noisy > acc_ideal - 0.15,
        "noisy {acc_noisy} vs ideal {acc_ideal}"
    );
}

#!/usr/bin/env bash
# CI gate for spikemram. Stages:
#   1. tier-1 (the hard gate, command verbatim from ROADMAP.md): release
#      build of lib+bin, then the full test suite (debug profile)
#   2. all-targets compile: benches + examples must keep building
#   3. lint: rustfmt + clippy, warnings fatal
#   4. docs: rustdoc must emit zero warnings
#
# The default feature set is hermetic (no network, no xla_extension); see
# Cargo.toml and README.md for the `pjrt` feature.
set -euo pipefail
cd "$(dirname "$0")"

echo "==> tier-1: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

echo "==> compile all targets (benches, examples, bin)"
cargo build --all-targets --release

echo "==> fabric bench: compile + smoke run in --test mode"
cargo bench --bench fabric_scaling --no-run
SPIKEMRAM_BENCH_FAST=1 cargo bench --bench fabric_scaling -- --test

echo "==> hotpath bench: smoke run in --test mode (batched MVM engine)"
# Exercises the serial + batched fast paths under the release profile and
# refreshes BENCH_hotpath.json (the machine-readable perf trajectory).
cargo bench --bench hotpath --no-run
SPIKEMRAM_BENCH_FAST=1 cargo bench --bench hotpath -- --test

echo "==> lint: cargo fmt --check && cargo clippy -D warnings"
# --all-targets covers the fabric/ module (lib), its bench, example,
# and integration test with warnings fatal.
cargo fmt --check
cargo clippy --all-targets -- -D warnings

echo "==> docs: cargo doc --no-deps (warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "CI OK"

#!/usr/bin/env bash
# CI gate for spikemram. Stages:
#   1. tier-1 (the hard gate, command verbatim from ROADMAP.md): release
#      build of lib+bin, then the full test suite (debug profile)
#   2. all-targets compile: benches + examples must keep building
#   3. lint: rustfmt + clippy, warnings fatal
#   4. docs: rustdoc must emit zero warnings
#
# The default feature set is hermetic (no network, no xla_extension); see
# Cargo.toml and README.md for the `pjrt` feature.
set -euo pipefail
cd "$(dirname "$0")"

# Every bench/test record lands at the repo root regardless of the
# invoking process's cwd — BENCH_*.json is the cross-PR perf trajectory
# and must be where the harness reads it (PR 4 fix: PR 3's records were
# written relative to ambient cwd and never landed here).
export SPIKEMRAM_BENCH_DIR="$(pwd)"

echo "==> tier-1: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

echo "==> tier-1 perf records present at the repo root"
# cargo test (batch_identity, stream_e2e) writes fast-mode hotpath +
# sparsity + stream records through Harness::finish(); fail loudly if
# they didn't land.
ls -l BENCH_hotpath.json BENCH_sparsity.json BENCH_stream.json

echo "==> compile all targets (benches, examples, bin)"
cargo build --all-targets --release

echo "==> examples build as a dedicated target set (stream_infer et al.)"
cargo build --examples --release

echo "==> fabric bench: compile + smoke run in --test mode"
cargo bench --bench fabric_scaling --no-run
SPIKEMRAM_BENCH_FAST=1 cargo bench --bench fabric_scaling -- --test

echo "==> hotpath bench: smoke run in --test mode (batched MVM engine)"
# Exercises the serial + batched fast paths under the release profile and
# refreshes BENCH_hotpath.json (the machine-readable perf trajectory).
cargo bench --bench hotpath --no-run
SPIKEMRAM_BENCH_FAST=1 cargo bench --bench hotpath -- --test

echo "==> sparsity bench: smoke run in --test mode (S17 engine sweep)"
# Refreshes BENCH_sparsity.json under the release profile — the record
# behind the event-list / quantized expectation bands in EXPERIMENTS.md.
cargo bench --bench sparsity --no-run
SPIKEMRAM_BENCH_FAST=1 cargo bench --bench sparsity -- --test

echo "==> stream bench: smoke run in --test mode (S18 timestep sweep)"
# Refreshes BENCH_stream.json under the release profile — the record
# behind the per-timestep expectation bands in EXPERIMENTS.md.
cargo bench --bench stream --no-run
SPIKEMRAM_BENCH_FAST=1 cargo bench --bench stream -- --test

echo "==> obs bench: smoke run in --test mode (S20 tracing overhead)"
# Writes BENCH_obs.json: macro MVM at B ∈ {1, 64} with tracing off/on —
# the record behind the §Perf tracing-overhead band in EXPERIMENTS.md.
cargo bench --bench obs --no-run
SPIKEMRAM_BENCH_FAST=1 cargo bench --bench obs -- --test
ls -l BENCH_obs.json

echo "==> trace CLI smoke (S20): Perfetto export must land and parse"
# `spikemram trace` serves a short synthetic stream workload with every
# kind enabled and writes results/trace_<seed>.json. The exporter
# round-trips the exact bytes through util::json::parse before writing
# (a hard error otherwise), so existence == parseability here; the
# parse is additionally asserted by rust/tests/obs_trace.rs in tier-1.
cargo run --release --quiet -- trace --seed 7 --sessions 2 --steps 2
ls -l results/trace_7.json

echo "==> EX4 reliability smoke sweep (S19 fault-injection runtime)"
# A small uptime sweep through the release binary: drift, recalibrate,
# scrub. Hard-fails if the CSV artifact does not land.
cargo run --release --quiet -- reliability --seed 7
ls -l results/ex4_reliability.csv

echo "==> EX5 overload smoke sweep (S21 serving control plane)"
# A small paced open-loop sweep through the release binary: calibrate
# capacity, offer 0.5x..8x, show the shed-rate knee with bounded p99.
# Hard-fails if the CSV or the machine-readable record does not land.
cargo run --release --quiet -- overload --seed 7 --frames 96
ls -l results/ex5_overload.csv BENCH_overload.json

echo "==> EX6 endurance smoke sweep (S22 mission-clock runtime)"
# A small three-arm mission sweep through the release binary: the mission
# clock drives drift/scrub/recalibrate with no manual fault calls, plus
# the wear-ceiling degrade demo. Hard-fails if the CSV or the
# machine-readable record does not land.
cargo run --release --quiet -- endurance --seed 7 --train 60 --test 10 --epochs 2
ls -l results/ex6_endurance.csv BENCH_endurance.json

echo "==> EX7 serving smoke sweep (S23 wire front end over loopback TCP)"
# A small open-loop sweep through the release binary where every frame
# crosses a real TCP socket: calibrate wire capacity, offer 0.5x..4x,
# drain each point gracefully. Hard-fails if the CSV or the
# machine-readable record does not land.
cargo run --release --quiet -- serving --seed 7 --frames 24
ls -l results/ex7_serving.csv BENCH_serving.json

echo "==> S23 net smoke: serve --listen + loadgen against a live server"
# Boot the stream backend on an ephemeral loopback port in the
# background, wait for the bound address to land in the addr file,
# drive a short closed-loop burst through `spikemram loadgen`, then
# stop the server with a wire drain and reap it. `wait` propagates a
# non-zero exit from the server process (set -e makes that fatal).
NET_ADDR_FILE="$(mktemp)"
rm -f "$NET_ADDR_FILE"
cargo run --release --quiet -- serve --backend stream --seed 7 \
    --listen 127.0.0.1:0 --listen-addr-file "$NET_ADDR_FILE" &
NET_PID=$!
for _ in $(seq 1 100); do
    [ -s "$NET_ADDR_FILE" ] && break
    sleep 0.1
done
[ -s "$NET_ADDR_FILE" ] || { echo "serve --listen never bound"; exit 1; }
cargo run --release --quiet -- loadgen --connect "$(cat "$NET_ADDR_FILE")" \
    --mode closed --connections 2 --frames 8 --drain
wait "$NET_PID"
rm -f "$NET_ADDR_FILE"

echo "==> S21 chaos soak (panic isolation, restart, accounting closure)"
# Re-runs the supervision chaos tests under the release-profile lib on
# top of their tier-1 (dev-profile) run: injected panics, bitwise
# session recovery, no frame both shed and served.
cargo test --release --test supervisor_chaos -q

echo "==> lint: cargo fmt --check && cargo clippy -D warnings (hard gate)"
# --all-targets covers the fabric/ module (lib), its bench, example,
# and integration test with warnings fatal.
cargo fmt --check
cargo clippy --all-targets -- -D warnings

echo "==> docs: cargo doc --no-deps (warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "CI OK"

//! Wire front-end walkthrough (DESIGN.md S23): boot a streaming
//! backend behind the TCP server on a loopback ephemeral port, then
//! talk to it the way a remote client would — open a session, stream
//! rate-coded event frames, read the evidence back frame by frame,
//! query the server's metrics document, and drain it gracefully.
//!
//! ```bash
//! cargo run --release --example net_client
//! ```
//!
//! The same protocol works across machines: run `spikemram serve
//! --backend stream --listen 0.0.0.0:7070` on the server side and
//! point [`NetClient::connect`] (or `spikemram loadgen --connect`) at
//! it.

use anyhow::Result;

use spikemram::config::{
    FabricConfig, LevelMap, MacroConfig, StreamConfig,
};
use spikemram::net::{NetBackend, NetClient, NetServer, Response};
use spikemram::snn::{Dataset, Mlp};
use spikemram::stream::{
    FrameEncoder, StreamServer, StreamServerConfig, StreamSpec, TemporalCode,
};

fn main() -> Result<()> {
    // Server side: the digit MLP on a 2×2 mesh, a streaming session
    // server over it, and the wire front end on an ephemeral port. In
    // production these live in another process (`spikemram serve
    // --listen`); in-process keeps the example self-contained.
    let t_steps = 8;
    let spec = StreamSpec {
        model: Mlp::new(42 ^ 0x7),
        calib: Dataset::generate(24, 42),
        mcfg: MacroConfig::default(),
        fabric: FabricConfig::square(2),
        level_map: LevelMap::DeviceTrue,
        stream: StreamConfig {
            t_steps,
            ..StreamConfig::default()
        },
    };
    let backend = StreamServer::start(spec, StreamServerConfig::default())?;
    let net = NetServer::start(NetBackend::Stream(backend), "127.0.0.1:0")?;
    let addr = net.addr().to_string();
    println!("serving on {addr}");

    // Client side: plain blocking TCP, one frame per request.
    let mut client = NetClient::connect(&addr)?;
    let session = client.open_session()?;
    println!("opened session {session}");

    let digits = Dataset::generate(1, 4242);
    let label = digits.examples[0].label;
    let enc = FrameEncoder::new(TemporalCode::Rate, t_steps, 255);
    let frames = enc.encode_frames(&digits.features_u8(0));
    println!("\nstreaming digit {label} over {t_steps} timesteps:");
    println!("{:>4} {:>8} {:>8}", "t", "events", "argmax");
    for f in &frames {
        match client.stream_frame(session, f.clone())? {
            Response::Frame { t, label, .. } => {
                println!("{t:>4} {:>8} {label:>8}", f.len());
            }
            Response::Shed {
                reason,
                retry_after_ms,
            } => {
                // Near capacity this is the expected backpressure
                // signal; a real client would sleep and resubmit.
                println!(
                    "   shed ({reason}), retry after {retry_after_ms:.2} ms"
                );
            }
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }
    let (t, out_v, predicted) = client.close_session(session)?;
    let best = out_v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nafter {t} steps: predicted {predicted} (true {label}), \
         top evidence {best:.3}"
    );

    // The server's whole metrics document travels over the same wire.
    let snapshot = client.metrics()?;
    let requests = snapshot
        .get("requests")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    let wire_requests = snapshot
        .get("net")
        .and_then(|n| n.get("wire_requests"))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    println!(
        "server saw {requests} backend requests \
         ({wire_requests} frames on the wire)"
    );

    // Graceful shutdown over the wire: live connections close on
    // frame boundaries and the server reports whether the drain shed
    // anything.
    let (drain_ms, shed, clean) = client.drain(10_000.0)?;
    println!(
        "drained in {drain_ms:.1} ms (shed {shed}, clean {clean})"
    );
    net.wait();
    Ok(())
}

//! Serving driver: the event-driven batching server under a Poisson-ish
//! open load, on either backend:
//!
//! ```bash
//! cargo run --release --example macro_server -- --backend sim  --requests 2000
//! cargo run --release --example macro_server -- --backend pjrt --requests 2000
//! ```
//!
//! Reports latency percentiles and throughput; with `--backend pjrt` the
//! compute path is the AOT-compiled JAX/Pallas artifact executed via the
//! PJRT CPU client (python never runs here).

use std::time::{Duration, Instant};

use spikemram::config::MacroConfig;
use spikemram::coordinator::{BackendKind, MacroServer, ServerConfig};
use spikemram::util::cli::Args;
use spikemram::util::rng::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("requests", 2000);
    let workers = args.get_usize("workers", 4);
    let batch = args.get_usize("batch", 8);
    let rate_rps = args.get_f64("rate", 0.0); // 0 = closed loop, max rate
    let backend_name = args.get_str("backend", "sim");
    let backend = match backend_name.as_str() {
        "sim" => BackendKind::Sim,
        "pjrt" => BackendKind::Pjrt {
            artifacts_dir: args.get_str("artifacts", "artifacts"),
        },
        other => {
            eprintln!("unknown backend {other:?} (sim|pjrt)");
            std::process::exit(1);
        }
    };

    let cfg = MacroConfig::default();
    let mut rng = Rng::new(args.get_u64("seed", 99));
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();

    println!(
        "starting server: backend={backend_name}, {workers} workers, \
         max batch {batch}"
    );
    let server = MacroServer::start(
        cfg.clone(),
        codes,
        ServerConfig {
            workers,
            max_batch: batch,
            batch_timeout: Duration::from_micros(
                args.get_u64("timeout-us", 200),
            ),
            backend,
        },
    )
    .expect("server start");

    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        if rate_rps > 0.0 {
            // Open-loop arrivals at the requested rate.
            let due = t0 + Duration::from_secs_f64(i as f64 / rate_rps);
            if let Some(wait) = due.checked_duration_since(Instant::now()) {
                std::thread::sleep(wait);
            }
        }
        let x: Vec<u32> = (0..cfg.rows).map(|_| rng.below(256) as u32).collect();
        pending.push(server.submit(x));
    }
    for rx in pending {
        rx.recv().expect("reply");
    }
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "\n{n} requests in {:.2} s → {:.0} req/s \
         ({:.2e} MAC/s through the macro)",
        wall,
        n as f64 / wall,
        n as f64 * (cfg.rows * cfg.cols) as f64 / wall
    );
    println!("{}", server.metrics.summary());
    server.shutdown();
}

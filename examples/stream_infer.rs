//! Temporal streaming walkthrough (DESIGN.md S18): train the digit MLP,
//! deploy it as a spiking network on a 2×2 fabric mesh, and watch one
//! digit stream through time — per-timestep spike counts, running
//! readout evidence, and the accuracy-vs-T trade.
//!
//! ```bash
//! cargo run --release --example stream_infer
//! ```

use anyhow::Result;

use spikemram::config::{
    FabricConfig, LevelMap, MacroConfig, StreamConfig,
};
use spikemram::snn::{self, Dataset};
use spikemram::stream::{
    collect_frames, FrameEncoder, PoissonStream, SpikingMlp, TemporalCode,
};

fn main() -> Result<()> {
    let cfg = MacroConfig::default();
    println!("training the float MLP on 300 synthetic digits…");
    let train = Dataset::generate(300, 42);
    let test = Dataset::generate(40, 4242);
    let (model, acc) = snn::train(&train, 6, 42);
    println!("float train accuracy: {acc:.3}");

    let mut mlp = SpikingMlp::from_float(
        &model,
        &train,
        &cfg,
        FabricConfig::square(2),
        LevelMap::DeviceTrue,
        &StreamConfig::default(),
    )?;

    // One digit, timestep by timestep: evidence accumulates on the
    // readout membranes while hidden spikes ripple through the mesh.
    let x = test.features_u8(0);
    let label = test.examples[0].label;
    let t_steps = 8;
    let enc = FrameEncoder::new(TemporalCode::Rate, t_steps, 255);
    let frames = enc.encode_frames(&x);
    println!("\nstreaming digit {label} over {t_steps} timesteps:");
    println!("{:>4} {:>9} {:>9} {:>9} {:>7}", "t", "in", "h1", "h2", "argmax");
    mlp.reset();
    for (t, f) in frames.iter().enumerate() {
        let step = mlp.step_frame(f);
        println!(
            "{:>4} {:>9} {:>9} {:>9} {:>7}",
            t,
            f.len(),
            step.spikes[0],
            step.spikes[1],
            mlp.label()
        );
    }
    println!("prediction after {t_steps} steps: {}", mlp.label());

    // The temporal knob: accuracy and energy vs T.
    println!("\naccuracy / energy vs timesteps on {} digits:", test.len());
    println!(
        "{:>4} {:>10} {:>14} {:>12} {:>11}",
        "T", "accuracy", "energy/inf", "spikes/inf", "occupancy"
    );
    for t in [1usize, 4, 16] {
        let enc = FrameEncoder::new(TemporalCode::Rate, t, 255);
        let mut correct = 0usize;
        let mut energy = 0.0f64;
        let mut spikes = 0u64;
        let mut occ = 0.0f64;
        for i in 0..test.len() {
            let run = mlp.run(&enc.encode_frames(&test.features_u8(i)));
            if run.label == test.examples[i].label {
                correct += 1;
            }
            energy += run.stats.energy.total_pj();
            spikes += run.stats.spikes_total();
            occ += run.stats.occupancy();
        }
        let n = test.len() as f64;
        println!(
            "{:>4} {:>10.3} {:>11.1} pJ {:>12.0} {:>10.1} %",
            t,
            correct as f64 / n,
            energy / n,
            spikes as f64 / n,
            100.0 * occ / n
        );
    }

    // DVS-style traffic: pipelined execution over a Poisson stream.
    let mut dvs = PoissonStream::uniform(256, 16, 0.1, 7);
    let frames = collect_frames(&mut dvs);
    let run = mlp.run_pipelined(&frames);
    println!(
        "\nPoisson stream (16 frames, 10 % density, pipelined): \
         {} input spikes, {:.1} pJ, occupancy {:.1} %",
        run.stats.in_spikes,
        run.stats.energy.total_pj(),
        run.stats.occupancy() * 100.0
    );
    Ok(())
}

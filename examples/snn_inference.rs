//! End-to-end validation driver (experiment E9, DESIGN.md §5): the full
//! deployment pipeline of the paper's motivating workload —
//!
//!   synthetic digits → train float MLP (256-128-128-16) →
//!   quantize weights to the macro's 2-bit conductance levels →
//!   run every matmul through the event-driven spiking macro simulation →
//!   report accuracy vs float, energy/inference, latency, TOPS/W,
//!   plus the device-true vs ideal-level and droop-mode ablations.
//!
//! ```bash
//! cargo run --release --example snn_inference [-- --train 600 --test 300]
//! ```
//! The run is recorded in EXPERIMENTS.md §E9.

use spikemram::config::{LevelMap, MacroConfig, NonIdeality};
use spikemram::energy::tops_per_watt;
use spikemram::repro::report;
use spikemram::snn::{self, MacroMlp};
use spikemram::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n_train = args.get_usize("train", 600);
    let n_test = args.get_usize("test", 300);
    let epochs = args.get_usize("epochs", 8);
    let seed = args.get_u64("seed", 2025);

    println!("== E9: end-to-end SNN inference on the spiking CIM macro ==\n");
    let train_data = snn::Dataset::generate(n_train, seed);
    let test_data = snn::Dataset::generate(n_test, seed ^ 0x5a5a);
    println!(
        "dataset: {n_train} train / {n_test} test synthetic digits (16×16, 8-bit)"
    );

    // --- float baseline -------------------------------------------------
    let t0 = std::time::Instant::now();
    let (model, train_acc) = snn::train(&train_data, epochs, seed);
    let float_acc = snn::accuracy(&model, &test_data);
    println!(
        "float MLP 256-128-128-16: train {train_acc:.3}, test {float_acc:.3} \
         (trained in {:.1} s)",
        t0.elapsed().as_secs_f64()
    );

    // --- macro deployment (device-true levels) --------------------------
    let cfg = MacroConfig::default();
    let mut mm =
        MacroMlp::from_float(&model, &train_data, &cfg, LevelMap::DeviceTrue);
    let t1 = std::time::Instant::now();
    let (acc, stats) = mm.evaluate(&test_data);
    let wall = t1.elapsed().as_secs_f64();
    let n = n_test as f64;
    let tops_w = tops_per_watt(stats.macs * 2, stats.energy.total_fj());
    println!("\nmacro (device-true 2-bit levels, ideal circuits):");
    println!("  accuracy        {acc:.3}  (float {float_acc:.3})");
    println!(
        "  energy          {:.2} nJ total, {:.1} pJ/inference",
        stats.energy.total_pj() / 1000.0,
        stats.energy.total_pj() / n
    );
    println!(
        "  sim latency     {:.2} µs/inference (3 dependent layers)",
        stats.latency_ns / n / 1000.0
    );
    println!("  efficiency      {tops_w:.1} TOPS/W on executed MACs");
    println!(
        "  throughput      {:.0} inferences/s of wall-clock simulation",
        n / wall
    );

    // --- ablation 1: idealized equally-spaced levels ---------------------
    let ideal_cfg = MacroConfig {
        level_map: LevelMap::IdealLinear,
        ..cfg.clone()
    };
    let mut mm_ideal = MacroMlp::from_float(
        &model,
        &train_data,
        &ideal_cfg,
        LevelMap::IdealLinear,
    );
    let (acc_ideal, _) = mm_ideal.evaluate(&test_data);

    // --- ablation 2: realistic analog non-idealities ---------------------
    let noisy_cfg = MacroConfig {
        nonideal: NonIdeality::realistic(),
        ..cfg.clone()
    };
    let mut mm_noisy = MacroMlp::from_float(
        &model,
        &train_data,
        &noisy_cfg,
        LevelMap::DeviceTrue,
    );
    let (acc_noisy, _) = mm_noisy.evaluate(&test_data);

    // --- ablation 3: no clamp+current-mirror (Fig 7b end-to-end) --------
    let droop_cfg = MacroConfig {
        nonideal: NonIdeality {
            clamp_current_mirror: false,
            ..NonIdeality::ideal()
        },
        ..cfg.clone()
    };
    let mut mm_droop = MacroMlp::from_float(
        &model,
        &train_data,
        &droop_cfg,
        LevelMap::DeviceTrue,
    );
    let (acc_droop, _) = mm_droop.evaluate(&test_data);

    println!("\nablations (test accuracy):");
    println!("  device-true levels, ideal circuits : {acc:.3}");
    println!("  idealized equal-spaced levels      : {acc_ideal:.3}");
    println!("  realistic non-idealities           : {acc_noisy:.3}");
    println!("  without clamp+current-mirror       : {acc_droop:.3}  ← §IV-B");

    let summary = format!(
        "E9 end-to-end SNN (seed {seed}, {n_train}/{n_test} split)\n\
         float_acc,{float_acc:.4}\nmacro_acc,{acc:.4}\n\
         ideal_levels_acc,{acc_ideal:.4}\nnoisy_acc,{acc_noisy:.4}\n\
         droop_acc,{acc_droop:.4}\n\
         energy_pj_per_inference,{:.2}\nlatency_ns_per_inference,{:.2}\n\
         tops_per_watt,{tops_w:.2}\n",
        stats.energy.total_pj() / n,
        stats.latency_ns / n,
    );
    let path = report::save("e9_snn_inference.csv", &summary);
    println!("\nrecorded to {}", path.display());
}

//! Quickstart: one spiking MVM on the macro, end to end.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the public API in the order a new user meets it: configure the
//! macro (Table I defaults) → program 2-bit weights → feed an 8-bit input
//! vector → read the dual-spike outputs back as digital MACs → inspect
//! latency, energy, and the Eq. 2 check against the exact oracle.

use spikemram::config::MacroConfig;
use spikemram::energy::tops_per_watt;
use spikemram::macro_model::CimMacro;
use spikemram::util::rng::Rng;

fn main() {
    // 1. Table I configuration: 128×128 3T-2MTJ, 1.1 V, R_LRS = 1 MΩ,
    //    TMR 100 %, T_bit = 0.2 ns, C_rt = C_com = 200 fF.
    let cfg = MacroConfig::default();
    println!(
        "macro: {}×{} cells, V_read {:.0} mV, α = {:.3} ns/(µS·ns)",
        cfg.rows,
        cfg.cols,
        cfg.v_read() * 1e3,
        cfg.alpha()
    );

    // 2. Program weights: 2-bit codes (0..=3) map to the series-stack
    //    conductances {1/6, 1/5, 1/4, 1/3} µS.
    let mut rng = Rng::new(7);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    let mut macro_ = CimMacro::new(cfg.clone());
    macro_.program(&codes);

    // 3. An 8-bit input vector → dual-spike pairs → event-driven MVM.
    let x: Vec<u32> = (0..cfg.rows).map(|_| rng.below(256) as u32).collect();
    let result = macro_.mvm(&x);

    // 4. Outputs: inter-spike intervals (ns) and decoded MACs.
    println!("\nfirst four columns:");
    println!("  col | T_out (ns) | MAC (decoded) | MAC (oracle)");
    let oracle = macro_.ideal_mvm(&x);
    for c in 0..4 {
        println!(
            "  {c:>3} | {:>10.4} | {:>13.3} | {:>12.3}",
            result.t_out_ns[c], result.y_mac[c], oracle[c]
        );
    }
    let max_err = result
        .y_mac
        .iter()
        .zip(&oracle)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    println!("max |decode error| across 128 columns: {max_err:.2e}");

    // 5. The event-driven economics.
    println!(
        "\nlatency {:.1} ns  ({} spike events processed)",
        result.latency_ns, result.events
    );
    println!(
        "energy  {:.1} pJ  → {:.1} TOPS/W  (paper headline: 243.6)",
        result.energy.total_pj(),
        tops_per_watt(cfg.ops_per_mvm(), result.energy.total_fj())
    );
    let shares = result.energy.shares();
    println!(
        "breakdown: array {:.1} %, SMU {:.1} %, OSG {:.1} %, control {:.1} %",
        shares[0] * 100.0,
        shares[1] * 100.0,
        shares[2] * 100.0,
        shares[3] * 100.0
    );

    // 6. Sparsity is free: zero inputs emit no spikes, burn no array power.
    let sparse: Vec<u32> =
        x.iter().enumerate().map(|(i, &v)| if i % 8 == 0 { v } else { 0 }).collect();
    let r2 = macro_.mvm(&sparse);
    println!(
        "\n1/8-density input: energy {:.1} pJ ({:.0} % of dense), {} events",
        r2.energy.total_pj(),
        100.0 * r2.energy.total_fj() / result.energy.total_fj(),
        r2.events
    );
}

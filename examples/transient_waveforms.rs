//! Regenerates the paper's transient plots (Figs 3c, 5, 7b) as CSVs in
//! `results/`, plus a textual summary of each.
//!
//! ```bash
//! cargo run --release --example transient_waveforms
//! ```

use spikemram::config::MacroConfig;
use spikemram::repro::{fig3, fig5, fig7};

fn main() {
    let cfg = MacroConfig::default();

    // Fig 3(c): SMU — input spike pair, Event_flag_i, clamped V_in.
    let f3 = fig3::run(&cfg, 16); // value 16 → Δ = 3.2 ns
    print!("{}", fig3::render(&f3));

    // Fig 5: one column's full conversion (charge + compare phases).
    let f5 = fig5::run(&cfg);
    print!("\n{}", fig5::render(&f5));

    // Fig 7(b): V_charge droop with vs without the clamp+current mirror.
    let f7b = fig7::run_fig7b(&cfg, fig7::FIG7B_ACTIVE_ROWS);
    print!("\n{}", fig7::render_fig7b(&f7b));

    println!("\nall waveform CSVs written under results/ — columns are");
    println!("(t_ns, signal...) and plot directly with any CSV tool.");
}

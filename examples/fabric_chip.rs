//! Fabric chip walkthrough (DESIGN.md S15): place a 512×512 weight
//! matrix onto a 4×4 mesh of macros, run one routed MVM, and inspect the
//! placement map, NoC traffic, and the energy ledger with its new `noc`
//! category.
//!
//! ```bash
//! cargo run --release --example fabric_chip
//! ```

use spikemram::config::{FabricConfig, LevelMap, MacroConfig};
use spikemram::coordinator::TiledMatrix;
use spikemram::energy::tops_per_watt;
use spikemram::fabric::FabricChip;
use spikemram::util::rng::Rng;

fn main() {
    // 1. A weight matrix four macros wide and four tall (16 shards).
    let cfg = MacroConfig::default();
    let (k, n) = (512usize, 512usize);
    let mut rng = Rng::new(11);
    let codes: Vec<u8> = (0..k * n).map(|_| rng.below(4) as u8).collect();
    let tiled = TiledMatrix::new(&codes, k, n, cfg.rows);
    println!(
        "weights: {k}×{n} 2-bit codes → {}×{} tiles of {}×{}",
        tiled.row_tiles, tiled.col_tiles, cfg.rows, cfg.cols
    );

    // 2. Place onto a 4×4 mesh (serpentine, weight-stationary).
    let fabric = FabricConfig::square(4);
    let mut chip =
        FabricChip::new(&cfg, fabric, vec![tiled]).expect("placement fits");
    println!(
        "\nplacement ({} of {} tiles, I/O port at (0,0)):\n{}",
        chip.tiles_used(),
        chip.tiles_total(),
        chip.placement.render()
    );

    // 3. One routed MVM: ingress → distribute → 16 concurrent tile
    //    MVMs → gather to column heads → egress.
    let x: Vec<u32> = (0..k).map(|_| rng.below(256) as u32).collect();
    let (y, r) = chip.mvm(&x);

    // 4. Check the decoded MACs against the dense digital oracle.
    let levels = LevelMap::DeviceTrue.levels();
    let mut max_err = 0.0f64;
    for c in 0..n {
        let want: f64 = (0..k)
            .map(|row| x[row] as f64 * levels[codes[row * n + c] as usize])
            .sum();
        max_err = max_err.max((y[c] - want).abs());
    }
    println!("max |err| vs dense oracle over {n} columns: {max_err:.2e}");

    // 5. The chip-level economics: NoC on top of the macro ledger.
    println!(
        "\nlatency {:.1} ns  ({} packets, {} flits, {} hops routed)",
        r.latency_ns, r.packets, r.flits, r.hops
    );
    let e = &r.energy;
    println!(
        "energy  {:.1} pJ → {:.1} TOPS/W on {} macros",
        e.total_pj(),
        tops_per_watt(
            cfg.ops_per_mvm() * chip.tiles_used() as u64,
            e.total_fj()
        ),
        chip.tiles_used()
    );
    let s = e.shares();
    println!(
        "breakdown: array {:.1} %, SMU {:.1} %, OSG {:.1} %, \
         control {:.1} %, NoC {:.1} %",
        s[0] * 100.0,
        s[1] * 100.0,
        s[2] * 100.0,
        s[3] * 100.0,
        s[4] * 100.0
    );

    // 6. Event-driven to the wire: a silent input routes nothing.
    let zeros = vec![0u32; k];
    let (_, r0) = chip.mvm(&zeros);
    println!(
        "\nall-zero input: {} packets, {:.1} pJ NoC energy (the mesh \
         idles with the array)",
        r0.packets,
        r0.energy.noc_fj / 1000.0
    );
}

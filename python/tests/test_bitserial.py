"""Bit-serial kernel vs the full-precision kernel and the jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bitserial import bitserial_mvm
from compile.kernels.spiking_mvm import LEVELS_DEVICE_TRUE, LEVELS_IDEAL_LINEAR


def _rng(seed):
    return np.random.default_rng(seed)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    bits_per_pass=st.sampled_from([1, 2, 3, 4, 8]),
    b=st.sampled_from([1, 4]),
    levels=st.sampled_from([LEVELS_DEVICE_TRUE, LEVELS_IDEAL_LINEAR]),
)
def test_bitserial_equals_full_precision(seed, bits_per_pass, b, levels):
    rng = _rng(seed)
    x = rng.integers(0, 256, (b, 128)).astype(np.int32)
    codes = rng.integers(0, 4, (128, 128)).astype(np.int32)
    got = bitserial_mvm(
        jnp.asarray(x),
        jnp.asarray(codes),
        total_bits=8,
        bits_per_pass=bits_per_pass,
        levels=levels,
        alpha=0.05,
    )
    want = ref.spiking_mvm_ref(
        jnp.asarray(x, jnp.float32), jnp.asarray(codes), levels=levels
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=0.05)


def test_single_pass_is_identity_decomposition():
    rng = _rng(0)
    x = rng.integers(0, 256, (2, 128)).astype(np.int32)
    codes = rng.integers(0, 4, (128, 128)).astype(np.int32)
    full = bitserial_mvm(
        jnp.asarray(x), jnp.asarray(codes), bits_per_pass=8, alpha=0.05
    )
    split = bitserial_mvm(
        jnp.asarray(x), jnp.asarray(codes), bits_per_pass=2, alpha=0.05
    )
    np.testing.assert_allclose(full, split, rtol=1e-4, atol=0.1)


def test_zero_input_all_passes_zero():
    x = jnp.zeros((2, 128), jnp.int32)
    codes = jnp.ones((128, 128), jnp.int32)
    y = bitserial_mvm(x, codes, bits_per_pass=4)
    assert np.all(np.asarray(y) == 0.0)

"""L2 model tests: macro forward semantics, the signed-weight offset
scheme, MLP shape/value checks against a pure-numpy reference, and the
Fig 7b transient pair — everything `aot.py` lowers must be correct here
first (these run before the artifacts are trusted)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.spiking_mvm import (
    LEVELS_DEVICE_TRUE,
    LEVELS_IDEAL_LINEAR,
)


def _rng(seed):
    return np.random.default_rng(seed)


def _g(codes, levels=LEVELS_DEVICE_TRUE):
    return np.asarray(levels, np.float64)[codes]


# ------------------------------------------------------------- macro ----
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), b=st.sampled_from([1, 4, 8]))
def test_macro_forward_decodes_exact_macs(seed, b):
    rng = _rng(seed)
    x = rng.integers(0, 256, (b, 128)).astype(np.int32)
    codes = rng.integers(0, 4, (128, 128)).astype(np.int32)
    t_out, y = model.macro_forward(jnp.asarray(x), jnp.asarray(codes))
    want = x.astype(np.float64) @ _g(codes)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-4, atol=0.05)
    # T_out obeys Eq. 2 with the configured alpha.
    np.testing.assert_allclose(
        np.asarray(t_out),
        model.ALPHA * 0.2 * want,
        rtol=1e-4,
        atol=1e-3,
    )


def test_alpha_matches_rust_config():
    # rust config.rs::alpha() computes the same formula; the manifest
    # records this value and the runtime asserts equality.
    assert abs(model.ALPHA - 0.05) < 1e-12
    assert abs(model.alpha_from_params() - model.ALPHA) < 1e-15


def test_macro_forward_zero_input():
    x = jnp.zeros((2, 128), jnp.int32)
    codes = jnp.ones((128, 128), jnp.int32)
    t_out, y = model.macro_forward(x, codes)
    assert np.all(np.asarray(t_out) == 0.0)
    assert np.all(np.asarray(y) == 0.0)


# --------------------------------------------------------------- mlp ----
def _numpy_mlp(x, c1, c2, c3, scales, steps, levels):
    """Pure-numpy replica of model.mlp_forward (float64)."""
    g_mid = float(sum(LEVELS_DEVICE_TRUE) / 4.0)

    def layer(x, c, s):
        mac = x.astype(np.float64) @ _g(c, levels)
        off = g_mid * x.sum(axis=1, keepdims=True)
        return s * (mac - off)

    def requant(z, step):
        q = np.round(np.maximum(z, 0.0) / step)
        return np.clip(q, 0, 255).astype(np.int64)

    h = requant(layer(x, c1, scales[0]), steps[0])
    h = requant(layer(h, c2, scales[1]), steps[1])
    return layer(h, c3, scales[2])


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mlp_forward_matches_numpy_reference(seed):
    rng = _rng(seed)
    x = rng.integers(0, 256, (4, 256)).astype(np.int32)
    c1 = rng.integers(0, 4, (256, 128)).astype(np.int32)
    c2 = rng.integers(0, 4, (128, 128)).astype(np.int32)
    c3 = rng.integers(0, 4, (128, 16)).astype(np.int32)
    scales = np.array([0.01, 0.02, 0.05], np.float32)
    steps = np.array([3.0, 2.0], np.float32)
    got = model.mlp_forward(
        jnp.asarray(x),
        jnp.asarray(c1),
        jnp.asarray(c2),
        jnp.asarray(c3),
        jnp.asarray(scales),
        jnp.asarray(steps),
    )
    want = _numpy_mlp(x, c1, c2, c3, scales, steps, LEVELS_DEVICE_TRUE)
    # f32 vs f64 and round() boundary effects: allow small absolute slack.
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=0.5)


def test_ideal_levels_change_macro_macs():
    # The ablation knob must actually change the analog MACs (the MLP's
    # requantization can mask small deltas, so compare pre-activation).
    rng = _rng(123)
    x = rng.integers(0, 256, (2, 128)).astype(np.int32)
    codes = rng.integers(0, 4, (128, 128)).astype(np.int32)
    _, y_dev = model.macro_forward(jnp.asarray(x), jnp.asarray(codes))
    _, y_ideal = model.macro_forward(
        jnp.asarray(x), jnp.asarray(codes), levels=LEVELS_IDEAL_LINEAR
    )
    assert not np.allclose(np.asarray(y_dev), np.asarray(y_ideal), rtol=1e-3)
    assert LEVELS_IDEAL_LINEAR != LEVELS_DEVICE_TRUE
    # codes 0 and 3 coincide across maps; 1 and 2 must differ.
    g_dev = _g(codes, LEVELS_DEVICE_TRUE)
    g_ideal = _g(codes, LEVELS_IDEAL_LINEAR)
    mask12 = (codes == 1) | (codes == 2)
    assert np.all(g_dev[mask12] != g_ideal[mask12])
    assert np.all(g_dev[~mask12] == g_ideal[~mask12])


# ---------------------------------------------------------- fig 7(b) ----
def test_fig7b_droop_below_mirror_everywhere():
    rng = _rng(7)
    t_in = jnp.asarray(
        rng.uniform(1.0, 10.0, (128,)).astype(np.float32)
    )
    g = jnp.asarray(
        rng.choice(LEVELS_DEVICE_TRUE, (128,)).astype(np.float32)
    )
    vm, vd = model.fig7b_transient(t_in, g, dt=0.01, n_steps=1000)
    vm = np.asarray(vm)
    vd = np.asarray(vd)
    assert vm.shape == vd.shape == (1000,)
    # droop trace never exceeds the mirrored trace, and both are monotone
    # non-decreasing (charging only).
    assert np.all(vd <= vm + 1e-7)
    assert np.all(np.diff(vm) >= -1e-9)
    assert np.all(np.diff(vd) >= -1e-9)
    # final droop is in the physically sensible band.
    droop = 1.0 - vd[-1] / vm[-1]
    assert 0.05 < droop < 0.9


def test_mlp_logit_scale_invariance_of_argmax():
    """Scaling the last-layer weight scale rescales logits but preserves
    the argmax — the property the quantizer relies on."""
    rng = _rng(11)
    x = rng.integers(0, 256, (4, 256)).astype(np.int32)
    c1 = rng.integers(0, 4, (256, 128)).astype(np.int32)
    c2 = rng.integers(0, 4, (128, 128)).astype(np.int32)
    c3 = rng.integers(0, 4, (128, 16)).astype(np.int32)
    steps = jnp.asarray([3.0, 2.0], jnp.float32)
    base = model.mlp_forward(
        jnp.asarray(x), jnp.asarray(c1), jnp.asarray(c2), jnp.asarray(c3),
        jnp.asarray([0.01, 0.02, 0.05], jnp.float32), steps,
    )
    scaled = model.mlp_forward(
        jnp.asarray(x), jnp.asarray(c1), jnp.asarray(c2), jnp.asarray(c3),
        jnp.asarray([0.01, 0.02, 0.5], jnp.float32), steps,
    )
    np.testing.assert_array_equal(
        np.argmax(np.asarray(base), axis=1),
        np.argmax(np.asarray(scaled), axis=1),
    )

"""pytest: every L1 Pallas kernel vs its pure-jnp oracle (allclose).

hypothesis sweeps shapes/dtypes/values — the CORE correctness signal
gating `make artifacts`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.encode import dualspike_decode, dualspike_encode
from compile.kernels.spiking_mvm import (
    LEVELS_DEVICE_TRUE,
    LEVELS_IDEAL_LINEAR,
    spiking_mvm,
)
from compile.kernels.transient import charge_transient

jax.config.update("jax_platform_name", "cpu")


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- MVM ----
@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    k=st.sampled_from([16, 64, 128, 256]),
    n=st.sampled_from([16, 128]),
    seed=st.integers(0, 2**31 - 1),
    levels=st.sampled_from([LEVELS_DEVICE_TRUE, LEVELS_IDEAL_LINEAR]),
)
def test_mvm_matches_ref_shapes(b, k, n, seed, levels):
    rng = _rng(seed)
    t_in = rng.integers(0, 256, (b, k)).astype(np.float32) * 0.2
    codes = rng.integers(0, 4, (k, n)).astype(np.int32)
    got = spiking_mvm(jnp.asarray(t_in), jnp.asarray(codes), levels=levels)
    want = ref.spiking_mvm_ref(
        jnp.asarray(t_in), jnp.asarray(codes), levels=levels
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    alpha=st.floats(1e-3, 10.0),
    bm=st.sampled_from([1, 4, 8]),
    bk=st.sampled_from([32, 64, 128]),
)
def test_mvm_alpha_and_blocks(seed, alpha, bm, bk):
    rng = _rng(seed)
    t_in = rng.uniform(0, 51.0, (8, 128)).astype(np.float32)
    codes = rng.integers(0, 4, (128, 128)).astype(np.int32)
    got = spiking_mvm(
        jnp.asarray(t_in), jnp.asarray(codes), alpha=alpha, bm=bm, bk=bk
    )
    want = ref.spiking_mvm_ref(jnp.asarray(t_in), jnp.asarray(codes), alpha=alpha)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_mvm_input_dtypes():
    rng = _rng(0)
    t_in = rng.uniform(0, 51.0, (4, 128))
    codes = rng.integers(0, 4, (128, 128))
    want = ref.spiking_mvm_ref(jnp.asarray(t_in, jnp.float32), jnp.asarray(codes))
    for tdt in (np.float32, np.float64):
        for cdt in (np.int8, np.int32, np.uint8):
            got = spiking_mvm(
                jnp.asarray(t_in.astype(tdt)), jnp.asarray(codes.astype(cdt))
            )
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_mvm_zero_input_is_zero():
    z = spiking_mvm(jnp.zeros((2, 128)), jnp.ones((128, 128), jnp.int32))
    assert np.all(np.asarray(z) == 0.0)


def test_mvm_linearity_in_t_in():
    """Eq. 2 is linear: doubling all T_in doubles T_out exactly."""
    rng = _rng(7)
    t_in = rng.uniform(0, 25.0, (4, 128)).astype(np.float32)
    codes = rng.integers(0, 4, (128, 128)).astype(np.int32)
    one = np.asarray(spiking_mvm(jnp.asarray(t_in), jnp.asarray(codes)))
    two = np.asarray(spiking_mvm(jnp.asarray(2 * t_in), jnp.asarray(codes)))
    np.testing.assert_allclose(two, 2 * one, rtol=1e-5)


# ------------------------------------------------------------- encode ----
@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 8),
    k=st.sampled_from([32, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
    t_bit=st.floats(0.05, 1.0),
)
def test_encode_matches_ref(b, k, seed, t_bit):
    x = _rng(seed).integers(0, 256, (b, k)).astype(np.int32)
    got = dualspike_encode(jnp.asarray(x), t_bit=t_bit)
    want = ref.dualspike_encode_ref(jnp.asarray(x), t_bit=t_bit)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), alpha=st.floats(0.01, 2.0))
def test_decode_inverts_encode_scale(seed, alpha):
    rng = _rng(seed)
    t = rng.uniform(0, 120.0, (4, 128)).astype(np.float32)
    got = dualspike_decode(jnp.asarray(t), alpha=alpha)
    want = ref.dualspike_decode_ref(jnp.asarray(t), alpha=alpha)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_encode_decode_roundtrip_exact_macs():
    """8-bit x, 2-bit codes: decode(mvm(encode(x))) == x @ G bit-true."""
    rng = _rng(3)
    x = rng.integers(0, 256, (4, 128)).astype(np.int32)
    codes = rng.integers(0, 4, (128, 128)).astype(np.int32)
    t_in = dualspike_encode(jnp.asarray(x))
    t_out = spiking_mvm(t_in, jnp.asarray(codes), alpha=0.05)
    y = dualspike_decode(t_out, alpha=0.05)
    want = ref.spiking_mvm_ref(
        jnp.asarray(x, jnp.float32), jnp.asarray(codes)
    )
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-2)


# ----------------------------------------------------------- transient ----
@settings(max_examples=8, deadline=None)
@given(
    k=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
    mirror=st.booleans(),
)
def test_transient_matches_ref(k, seed, mirror):
    rng = _rng(seed)
    t_in = rng.uniform(0, 8.0, (k,)).astype(np.float32)
    g = rng.choice([1 / 6, 1 / 5, 1 / 4, 1 / 3], (k,)).astype(np.float32)
    got = charge_transient(
        jnp.asarray(t_in), jnp.asarray(g), dt=0.05, n_steps=256, mirror=mirror
    )
    want = ref.charge_transient_ref(
        jnp.asarray(t_in), jnp.asarray(g), dt=0.05, n_steps=256, mirror=mirror
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_transient_droop_below_mirror():
    """Fig 7b: without the mirror, V_charge is strictly lower at the end."""
    t_in = jnp.full((128,), 10.0)
    g = jnp.full((128,), 1 / 3)
    vm = charge_transient(t_in, g, dt=0.01, n_steps=1000, mirror=True)
    vd = charge_transient(t_in, g, dt=0.01, n_steps=1000, mirror=False)
    assert float(vd[-1]) < float(vm[-1])
    droop = 1.0 - float(vd[-1]) / float(vm[-1])
    assert 0.05 < droop < 0.8  # paper: 39.6 % at 10 ns, same order


def test_transient_mirror_is_linear_ramp():
    """With all rows active, mirrored charging is an exact linear ramp."""
    t_in = jnp.full((16,), 100.0)  # never de-asserts within window
    g = jnp.full((16,), 0.25)
    v = np.asarray(charge_transient(t_in, g, dt=0.01, n_steps=500))
    dv = np.diff(v)
    np.testing.assert_allclose(dv, dv[0], rtol=1e-4)

"""L2: JAX behavioral model of the spiking CIM macro (build-time only).

Composes the L1 Pallas kernels into the forward paths that `aot.py` lowers
to HLO text for the Rust runtime:

* ``macro_forward``   — one 128x128 macro op: dual-spike encode -> temporal
                        MVM (Eq. 2) -> decode back to digital MAC values.
* ``mlp_forward``     — the end-to-end DNN workload: a 256-128-128-16 MLP
                        whose every matmul runs through macro semantics
                        (2-bit weight codes on device-true conductance
                        levels, 8-bit dual-spike activations).
* ``fig7b_transient`` — V_charge traces with/without the clamp+current-
                        mirror, the L2 oracle for the Rust circuit engine.

Signed weights use the conductance-offset scheme (DESIGN.md §7): the
effective weight of code c is  G(c) - G_mid  with  G_mid = mean(levels),
realized digitally by subtracting  G_mid * sum_i(x_i)  from each MAC —
the same trick a physical macro would implement with a reference column.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.encode import T_BIT_NS, dualspike_encode, dualspike_decode
from .kernels.spiking_mvm import (
    LEVELS_DEVICE_TRUE,
    LEVELS_IDEAL_LINEAR,
    spiking_mvm,
)
from .kernels.transient import charge_transient

# ---- Circuit constants (Table I + DESIGN.md §6 sizing) -------------------
V_READ = 0.1  # V  (V_clamp 400 mV - V_in,clamp 300 mV)
C_RT_FF = 200.0  # fF
C_COM_FF = 200.0  # fF
I_COM_UA = 2.0  # µA  (sized so max V_charge ~= 1.09 V < VDD 1.1 V)
K_MIRROR = 1.0  # current-mirror gain

#: OSG sensing gain alpha = k * V_read * C_com / (C_rt * I_com)  [ns/(µS·ns)]
ALPHA = K_MIRROR * V_READ * C_COM_FF / (C_RT_FF * I_COM_UA)

G_MID = sum(LEVELS_DEVICE_TRUE) / 4.0  # conductance offset for signed weights


def alpha_from_params(
    k_mirror: float = K_MIRROR,
    v_read: float = V_READ,
    c_rt_ff: float = C_RT_FF,
    c_com_ff: float = C_COM_FF,
    i_com_ua: float = I_COM_UA,
) -> float:
    """Eq. 2's alpha from circuit parameters (physical form, DESIGN.md §1)."""
    return k_mirror * v_read * c_com_ff / (c_rt_ff * i_com_ua)


def macro_forward(x, codes, *, levels=LEVELS_DEVICE_TRUE, alpha=ALPHA):
    """One macro op. x: int[B,K] in [0,255]; codes: int[K,N] in [0,3].

    Returns (t_out[B,N] ns, y[B,N] digital MAC = sum_i x_i * G(code_ij) µS).
    """
    t_in = dualspike_encode(x)
    t_out = spiking_mvm(t_in, codes, levels=levels, alpha=alpha)
    y = dualspike_decode(t_out, alpha=alpha)
    return t_out, y


def _macro_layer(x, codes, scale, levels):
    """Signed macro layer: scale * (MAC - G_mid * sum(x)). x int[B,K]."""
    _, mac = macro_forward(x, codes, levels=levels)
    offset = jnp.float32(G_MID) * jnp.sum(
        x.astype(jnp.float32), axis=1, keepdims=True
    )
    return scale * (mac - offset)


def _requant(z, step):
    """ReLU + uint8 requantization of activations (dual-spike range)."""
    q = jnp.round(jnp.maximum(z, 0.0) / step)
    return jnp.clip(q, 0.0, 255.0).astype(jnp.int32)


def mlp_forward(
    x, c1, c2, c3, scales, steps, *, levels=LEVELS_DEVICE_TRUE
):
    """End-to-end MLP on macro semantics.

    x: int[B,256] 8-bit pixels; c1 int[256,128], c2 int[128,128],
    c3 int[128,16] 2-bit weight codes; scales f32[3] per-layer weight
    scales; steps f32[2] activation quant steps. Returns f32[B,16] logits.
    """
    h = _requant(_macro_layer(x, c1, scales[0], levels), steps[0])
    h = _requant(_macro_layer(h, c2, scales[1], levels), steps[1])
    return _macro_layer(h, c3, scales[2], levels)


def mlp_forward_ideal(x, c1, c2, c3, scales, steps):
    """Ablation: same MLP on idealized equally-spaced conductance levels."""
    return mlp_forward(
        x, c1, c2, c3, scales, steps, levels=LEVELS_IDEAL_LINEAR
    )


def fig7b_transient(t_in, g, *, dt=0.01, n_steps=1000):
    """(V_mirror[n], V_droop[n]) charge traces for Fig 7(b)."""
    vm = charge_transient(
        t_in, g, dt=dt, n_steps=n_steps, v_read=V_READ, c_ff=C_RT_FF,
        k_mirror=K_MIRROR, mirror=True,
    )
    vd = charge_transient(
        t_in, g, dt=dt, n_steps=n_steps, v_read=V_READ, c_ff=C_RT_FF,
        k_mirror=K_MIRROR, mirror=False,
    )
    return vm, vd

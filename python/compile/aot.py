"""AOT lowering: jax (L2+L1) -> HLO *text* artifacts for the Rust runtime.

HLO text, NOT ``lowered.compile()``/``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once by ``make artifacts``; Python never executes at request time.
Every entry is lowered with return_tuple=True so the Rust side unwraps
with ``to_tuple1()`` / ``to_tuple()``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.spiking_mvm import spiking_mvm


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _mvm_entry(t_in, codes):
    return (spiking_mvm(t_in, codes, alpha=model.ALPHA),)


def _macro_entry(x, codes):
    return model.macro_forward(x, codes)


def _mlp_entry(x, c1, c2, c3, scales, steps):
    return (model.mlp_forward(x, c1, c2, c3, scales, steps),)


def _mlp_ideal_entry(x, c1, c2, c3, scales, steps):
    return (model.mlp_forward_ideal(x, c1, c2, c3, scales, steps),)


def _fig7b_entry(t_in, g):
    return model.fig7b_transient(t_in, g, dt=0.01, n_steps=1000)


#: name -> (fn, example args). Shapes are the contract with rust/src/runtime.
ENTRIES = {
    "spiking_mvm_b8_128x128": (_mvm_entry, (_f32(8, 128), _i32(128, 128))),
    "spiking_mvm_b32_128x128": (_mvm_entry, (_f32(32, 128), _i32(128, 128))),
    "macro_fwd_b8": (_macro_entry, (_i32(8, 128), _i32(128, 128))),
    "mlp_fwd_b16": (
        _mlp_entry,
        (
            _i32(16, 256),
            _i32(256, 128),
            _i32(128, 128),
            _i32(128, 16),
            _f32(3),
            _f32(2),
        ),
    ),
    "mlp_fwd_ideal_b16": (
        _mlp_ideal_entry,
        (
            _i32(16, 256),
            _i32(256, 128),
            _i32(128, 128),
            _i32(128, 16),
            _f32(3),
            _f32(2),
        ),
    ),
    "fig7b_transient": (_fig7b_entry, (_f32(128), _f32(128))),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out",
        default="../artifacts/model.hlo.txt",
        help="path of the primary artifact (its dir receives all entries)",
    )
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {}
    for name, (fn, example) in ENTRIES.items():
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "args": [
                {"shape": list(a.shape), "dtype": str(a.dtype)}
                for a in example
            ],
            "alpha": model.ALPHA,
            "t_bit_ns": 0.2,
        }
        print(f"wrote {path} ({len(text)} chars)")

    # Primary artifact: the single-macro MVM (the Makefile's sentinel file).
    primary = os.path.join(out_dir, "spiking_mvm_b8_128x128.hlo.txt")
    with open(primary) as f, open(args.out, "w") as g:
        g.write(f.read())
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} + manifest.json ({len(ENTRIES)} entries)")


if __name__ == "__main__":
    main()

"""Pure-jnp correctness oracles for every L1 kernel (no Pallas).

pytest compares each kernel against these under hypothesis-driven
shape/dtype/value sweeps — the CORE build-time correctness signal.
"""

from __future__ import annotations

import jax.numpy as jnp

from .spiking_mvm import LEVELS_DEVICE_TRUE


def codes_to_conductance(codes, levels=LEVELS_DEVICE_TRUE):
    """int[?, ?] 2-bit codes -> f32 conductances (µS) via the level LUT."""
    lut = jnp.asarray(levels, jnp.float32)
    return lut[codes.astype(jnp.int32)]


def spiking_mvm_ref(t_in, codes, *, levels=LEVELS_DEVICE_TRUE, alpha=1.0):
    """Eq. 2: T_out = alpha * T_in @ G(codes)."""
    g = codes_to_conductance(codes, levels)
    return jnp.float32(alpha) * (t_in.astype(jnp.float32) @ g)


def dualspike_encode_ref(x, *, t_bit=0.2):
    return x.astype(jnp.float32) * jnp.float32(t_bit)


def dualspike_decode_ref(t_out, *, alpha=1.0, t_bit=0.2):
    return t_out.astype(jnp.float32) / jnp.float32(alpha * t_bit)


def charge_transient_ref(
    t_in,
    g,
    *,
    dt=0.01,
    n_steps=1024,
    v_read=0.1,
    c_ff=200.0,
    k_mirror=1.0,
    mirror=True,
):
    """Euler V_charge trace; identical discretization to the kernel."""
    t_in = t_in.astype(jnp.float32)
    g = g.astype(jnp.float32)
    v = jnp.float32(0.0)
    out = []
    for s in range(n_steps):
        t = s * dt
        g_on = jnp.sum((t < t_in).astype(jnp.float32) * g)
        if mirror:
            dv = k_mirror * v_read * g_on * dt / c_ff
        else:
            dv = g_on * (v_read - v) * dt / c_ff
        v = v + dv
        out.append(v)
    return jnp.stack(out)

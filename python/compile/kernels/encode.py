"""L1 Pallas kernels: dual-spike (en/de)coding (SMU / OSG digital twins).

Encoding (SMU, paper §III-B): an 8-bit digital value x becomes a spike pair
whose inter-spike interval is T_in = x * T_bit (T_bit = 0.2 ns, Table I).

Decoding (OSG output, §III-C): the output interval T_out maps back to the
digital MAC value  y = T_out / (alpha * T_bit)  in conductance units (µS).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

T_BIT_NS = 0.2  # Table I: one LSB of input = 0.2 ns of spike interval.


def _encode_kernel(x_ref, o_ref, *, t_bit):
    o_ref[...] = x_ref[...].astype(jnp.float32) * jnp.float32(t_bit)


@functools.partial(
    jax.jit, static_argnames=("t_bit", "block", "interpret")
)
def dualspike_encode(
    x: jax.Array,
    *,
    t_bit: float = T_BIT_NS,
    block: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """uint8/int32[B, K] digital inputs -> f32[B, K] spike intervals (ns)."""
    b, k = x.shape
    block = min(block, k)
    assert k % block == 0, (k, block)
    return pl.pallas_call(
        functools.partial(_encode_kernel, t_bit=t_bit),
        grid=(b, k // block),
        in_specs=[pl.BlockSpec((1, block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.int32))


def _decode_kernel(t_ref, o_ref, *, scale):
    o_ref[...] = t_ref[...] * jnp.float32(scale)


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "t_bit", "block", "interpret"),
)
def dualspike_decode(
    t_out: jax.Array,
    *,
    alpha: float = 1.0,
    t_bit: float = T_BIT_NS,
    block: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """f32[B, N] output intervals (ns) -> f32[B, N] MAC values (µS units)."""
    b, n = t_out.shape
    block = min(block, n)
    assert n % block == 0, (n, block)
    scale = 1.0 / (alpha * t_bit)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=(b, n // block),
        in_specs=[pl.BlockSpec((1, block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(t_out.astype(jnp.float32))

"""L1 Pallas kernel: the dual-spike temporal MAC (digital twin of the macro).

The paper's crossbar computes, per column j,

    T_out[j] = alpha * sum_i T_in[i] * G_mem[i, j]          (Eq. 2)

where T_in are input inter-spike intervals and G_mem the 2-bit programmed
cell conductances. Here that is realized as a tiled matmul whose weight
operand is *expanded on the fly* from packed 2-bit codes to conductance
levels — the digital analogue of "weights live in the array, inputs stream
past" (DESIGN.md §8). One (bk, bn) = (128, 128) weight block mirrors one
physical crossbar macro and stays VMEM-resident for the whole k-step.

Units are normalized for f32 hygiene: time in ns, conductance in µS
(products are O(1..10) instead of O(1e-14)).

All kernels run with interpret=True (CPU PJRT); see DESIGN.md
§Hardware-Adaptation for the real-TPU mapping.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 4 conductance levels of the 3T-2MTJ cell (µS), ascending by code.
# Series stack J1+J2 with R_LRS=1 MΩ, TMR=100 %, R(J2)=2·R(J1):
#   R ∈ {6, 5, 4, 3} MΩ  →  G ∈ {1/6, 1/5, 1/4, 1/3} µS  (device-true).
LEVELS_DEVICE_TRUE = (1.0 / 6.0, 1.0 / 5.0, 1.0 / 4.0, 1.0 / 3.0)
# Idealized equally-spaced levels spanning the same range (ablation).
LEVELS_IDEAL_LINEAR = (
    1.0 / 6.0,
    1.0 / 6.0 + (1.0 / 3.0 - 1.0 / 6.0) / 3.0,
    1.0 / 6.0 + 2.0 * (1.0 / 3.0 - 1.0 / 6.0) / 3.0,
    1.0 / 3.0,
)


def _mvm_kernel(t_ref, codes_ref, o_ref, *, levels, nk):
    """One (bm, bn) output tile; grid axis 2 iterates k-blocks."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = codes_ref[...]  # (bk, bn) int32, values 0..3
    # One-hot expansion instead of gather: 4 compares + FMAs, which maps to
    # plain VPU ops on TPU (no dynamic-gather custom call).
    g = jnp.zeros(codes.shape, jnp.float32)
    for s, lv in enumerate(levels):
        g = g + jnp.float32(lv) * (codes == s).astype(jnp.float32)
    o_ref[...] += jnp.dot(
        t_ref[...], g, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit,
    static_argnames=("levels", "alpha", "bm", "bk", "bn", "interpret"),
)
def spiking_mvm(
    t_in: jax.Array,
    codes: jax.Array,
    *,
    levels: tuple[float, ...] = LEVELS_DEVICE_TRUE,
    alpha: float = 1.0,
    bm: int = 8,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Temporal MAC: ``alpha * t_in @ levels[codes]``.

    Args:
      t_in:  f32[B, K] input inter-spike intervals (ns), >= 0.
      codes: int32[K, N] 2-bit weight codes in {0, 1, 2, 3}.
      levels: static 4-tuple, code -> conductance (µS).
      alpha: OSG sensing gain (ns per µS·ns), Eq. 2.

    Returns: f32[B, N] output inter-spike intervals (ns).
    """
    b, k = t_in.shape
    k2, n = codes.shape
    assert k == k2, (t_in.shape, codes.shape)
    bm = min(bm, b)
    bk = min(bk, k)
    bn = min(bn, n)
    assert b % bm == 0 and k % bk == 0 and n % bn == 0, (b, k, n, bm, bk, bn)
    nk = k // bk
    out = pl.pallas_call(
        functools.partial(_mvm_kernel, levels=levels, nk=nk),
        grid=(b // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(t_in.astype(jnp.float32), codes.astype(jnp.int32))
    return jnp.float32(alpha) * out

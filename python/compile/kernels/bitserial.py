"""L1 Pallas kernel: bit-serial temporal MVM (§IV-B extension).

Digital twin of `rust/src/coding/bitserial.rs` + `CimMacro::mvm_bitserial`:
the 8-bit input is split into `passes` chunks of `bits_per_pass`, each
chunk runs through the same temporal-MAC kernel with its (short) window,
and the per-pass results recombine with digital shift-add:

    mac(x) = sum_p 2^(p·bits_per_pass) · mac(chunk_p)

Exact under ideal circuits (linearity of Eq. 2); the rust ablation layer
quantifies the error amplification under comparator offsets.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .spiking_mvm import LEVELS_DEVICE_TRUE, spiking_mvm


@functools.partial(
    jax.jit,
    static_argnames=(
        "total_bits",
        "bits_per_pass",
        "levels",
        "alpha",
        "t_bit",
        "interpret",
    ),
)
def bitserial_mvm(
    x: jax.Array,
    codes: jax.Array,
    *,
    total_bits: int = 8,
    bits_per_pass: int = 4,
    levels: tuple[float, ...] = LEVELS_DEVICE_TRUE,
    alpha: float = 1.0,
    t_bit: float = 0.2,
    interpret: bool = True,
) -> jax.Array:
    """Bit-serial MAC: int[B, K] digital inputs -> f32[B, N] MACs (µS·LSB).

    Returns the *recombined digital MAC* (already decoded), so callers
    compare directly against ``spiking_mvm`` decoded output.
    """
    assert 1 <= bits_per_pass <= total_bits
    passes = -(-total_bits // bits_per_pass)  # ceil div
    mask = (1 << bits_per_pass) - 1
    xi = x.astype(jnp.int32)
    out = None
    for p in range(passes):
        chunk = (xi >> (p * bits_per_pass)) & mask
        t_in = chunk.astype(jnp.float32) * jnp.float32(t_bit)
        t_out = spiking_mvm(
            t_in, codes, levels=levels, alpha=alpha, interpret=interpret
        )
        mac = t_out / jnp.float32(alpha * t_bit)
        w = jnp.float32(1 << (p * bits_per_pass))
        out = mac * w if out is None else out + mac * w
    return out

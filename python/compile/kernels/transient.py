"""L1 Pallas kernel: capacitor-charging transient (OSG digital twin).

Cross-check oracle for the Rust behavioral circuit engine (Fig 7b).
Simulates V_charge(t) on the result capacitor C_rt for one column while the
input spike windows are active:

  with clamp+current-mirror (paper's design):
      dV/dt = k * V_read * sum_i 1[t < T_in,i] * G_i / C_rt
  without (baseline, Fig 7b droop):
      dV/dt = sum_i 1[t < T_in,i] * G_i * (V_read - V) / C_rt

Units: t in ns, G in µS, C in fF, V in volts (µS·ns/fF = 1, so the Euler
update needs no unit factors).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transient_kernel(
    t_in_ref, g_ref, o_ref, *, dt, n_steps, v_read, c_ff, k_mirror, mirror
):
    t_in = t_in_ref[...]  # (K,)
    g = g_ref[...]  # (K,)

    def body(s, v):
        t = s * dt
        active = (t < t_in).astype(jnp.float32)
        g_on = jnp.sum(active * g)
        if mirror:
            dv = k_mirror * v_read * g_on * dt / c_ff
        else:
            dv = g_on * (v_read - v) * dt / c_ff
        v = v + dv
        o_ref[s] = v
        return v

    jax.lax.fori_loop(0, n_steps, body, jnp.float32(0.0))


@functools.partial(
    jax.jit,
    static_argnames=(
        "dt",
        "n_steps",
        "v_read",
        "c_ff",
        "k_mirror",
        "mirror",
        "interpret",
    ),
)
def charge_transient(
    t_in: jax.Array,
    g: jax.Array,
    *,
    dt: float = 0.01,
    n_steps: int = 1024,
    v_read: float = 0.1,
    c_ff: float = 200.0,
    k_mirror: float = 1.0,
    mirror: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Euler transient of V_charge. Returns f32[n_steps] voltage trace."""
    (k,) = t_in.shape
    assert g.shape == (k,)
    return pl.pallas_call(
        functools.partial(
            _transient_kernel,
            dt=dt,
            n_steps=n_steps,
            v_read=v_read,
            c_ff=c_ff,
            k_mirror=k_mirror,
            mirror=mirror,
        ),
        in_specs=[
            pl.BlockSpec(t_in.shape, lambda: (0,)),
            pl.BlockSpec(g.shape, lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((n_steps,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_steps,), jnp.float32),
        interpret=interpret,
    )(t_in.astype(jnp.float32), g.astype(jnp.float32))

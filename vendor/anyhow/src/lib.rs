//! Vendored, dependency-free subset of the `anyhow` error-handling API.
//!
//! The repo must build hermetically — no network, no registry — so instead
//! of depending on crates.io this path dependency re-implements exactly the
//! surface `spikemram` uses: [`Error`], [`Result`], the [`Context`] trait
//! (`.context(..)` / `.with_context(..)` on `Result` and `Option`), and the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros. Swapping back to the real
//! crate is a one-line change in the workspace `Cargo.toml`; no source
//! edits needed.
//!
//! Differences from the real crate (none observable to this repo's code):
//! * the cause chain is captured as rendered strings, not live trait
//!   objects, so `downcast` is not provided;
//! * no backtrace support.

use std::error::Error as StdError;
use std::fmt;

/// An error with a human-readable message and a rendered cause chain.
pub struct Error {
    msg: String,
    /// Outermost-first rendered causes (`Caused by:` lines in `{:?}`).
    chain: Vec<String>,
}

/// `anyhow`-style result alias: `Result<T>` defaults the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            chain: Vec::new(),
        }
    }

    /// Wrap a standard error, capturing its source chain.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error::from(error)
    }

    /// Attach a higher-level context message; `self` becomes the cause.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Error {
            msg: context.to_string(),
            chain,
        }
    }

    /// The outermost message plus each cause, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.chain.iter().map(String::as_str))
    }

    /// The innermost cause message (the root of the chain).
    pub fn root_cause(&self) -> &str {
        self.chain.last().unwrap_or(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            for cause in &self.chain {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if !self.chain.is_empty() {
            f.write_str("\n\nCaused by:")?;
            if self.chain.len() == 1 {
                write!(f, "\n    {}", self.chain[0])?;
            } else {
                for (i, cause) in self.chain.iter().enumerate() {
                    write!(f, "\n    {i}: {cause}")?;
                }
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        let mut chain = Vec::new();
        let mut source = error.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error {
            msg: error.to_string(),
            chain,
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

// One blanket impl over `E: Into<Error>` covers both `Result<T, E>` for any
// std error *and* `Result<T, anyhow::Error>` (via the reflexive `From`).
impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an [`Error`] unless the condition holds (the real
/// crate's `ensure!`, message forms included).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!(::std::concat!(
                "Condition failed: `",
                ::std::stringify!($cond),
                "`"
            ));
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !$cond {
            $crate::bail!($($tt)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_on_result_wraps_and_chains() {
        let r: Result<(), _> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(e.root_cause(), "gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let r: Result<u32, std::io::Error> = Ok(7);
        let v = r
            .with_context(|| -> String { panic!("must not evaluate") })
            .unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert!(Some(1u32).context("x").is_ok());
    }

    #[test]
    fn context_stacks_on_anyhow_results() {
        let r: Result<()> = Err(anyhow!("inner {}", 3));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "inner 3"]);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn bail_and_literal_forms() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed");
            }
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
        assert_eq!(f(5).unwrap(), 5);
    }

    #[test]
    fn ensure_bare_and_message_forms() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x != 0);
            ensure!(x <= 10, "too big: {} > 10", x);
            Ok(x)
        }
        assert!(f(0)
            .unwrap_err()
            .to_string()
            .contains("Condition failed"));
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11 > 10");
        assert_eq!(f(5).unwrap(), 5);
    }

    #[test]
    fn anyhow_accepts_string_expression() {
        let s = String::from("boom");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "boom");
    }

    #[test]
    fn alternate_display_prints_chain_inline() {
        let e = Error::msg("inner").context("mid").context("outer");
        assert_eq!(format!("{e:#}"), "outer: mid: inner");
    }
}

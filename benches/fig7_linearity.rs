//! Bench: Fig 7 regeneration (experiments E6/E7) — the linearity sweep
//! and the droop comparison, plus a robustness sweep of R² against analog
//! non-idealities (comparator offset, mirror gain error, MTJ variation).

use spikemram::benchlib::Harness;
use spikemram::config::{MacroConfig, NonIdeality};
use spikemram::macro_model::CimMacro;
use spikemram::repro::fig7;
use spikemram::util::rng::Rng;
use spikemram::util::stats::line_fit;

fn linearity_r2(cfg: &MacroConfig, seed: u64, points: usize) -> f64 {
    let mut m = if cfg.nonideal.sigma_r_d2d > 0.0
        || cfg.nonideal.comparator_offset_v > 0.0
        || cfg.nonideal.mirror_gain_sigma > 0.0
    {
        CimMacro::with_nonidealities(cfg.clone(), seed)
    } else {
        CimMacro::new(cfg.clone())
    };
    let mut rng = Rng::new(seed ^ 0x77);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    while xs.len() < points {
        let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
            .map(|_| rng.below(4) as u8)
            .collect();
        m.program(&codes);
        let x: Vec<u32> = (0..cfg.rows).map(|_| rng.below(256) as u32).collect();
        let r = m.mvm(&x);
        let ideal = m.ideal_mvm(&x);
        for c in 0..cfg.cols {
            if xs.len() >= points {
                break;
            }
            xs.push(ideal[c] * cfg.t_bit_ns);
            ys.push(r.t_out_ns[c]);
        }
    }
    line_fit(&xs, &ys).r2
}

fn main() {
    let mut h = Harness::new("fig7_linearity");
    let cfg = MacroConfig::default();

    h.bench_function("fig7a_sweep_512_points", |b| {
        b.iter(|| fig7::run_fig7a(&cfg, 512, 71))
    });
    h.bench_function("fig7b_droop_waveforms", |b| {
        b.iter(|| fig7::run_fig7b(&cfg, fig7::FIG7B_ACTIVE_ROWS))
    });

    println!();
    println!("{}", fig7::render_fig7a(&fig7::run_fig7a(&cfg, 4096, 71)));
    println!(
        "{}",
        fig7::render_fig7b(&fig7::run_fig7b(&cfg, fig7::FIG7B_ACTIVE_ROWS))
    );

    // Robustness: R² vs non-ideality magnitude (not in the paper, but the
    // natural question Fig 7a raises — how much analog error before the
    // "excellent linearity" claim degrades?).
    println!("linearity R² vs analog non-idealities (2048 points each):");
    println!("{:>34} {:>14}", "configuration", "R²");
    let configs: Vec<(&str, NonIdeality)> = vec![
        ("ideal", NonIdeality::ideal()),
        (
            "comparator offset 2 mV",
            NonIdeality {
                comparator_offset_v: 0.002,
                ..NonIdeality::ideal()
            },
        ),
        (
            "mirror gain σ 2 %",
            NonIdeality {
                mirror_gain_sigma: 0.02,
                ..NonIdeality::ideal()
            },
        ),
        (
            "MTJ d2d σ 5 %",
            NonIdeality {
                sigma_r_d2d: 0.05,
                ..NonIdeality::ideal()
            },
        ),
        ("realistic (all)", NonIdeality::realistic()),
    ];
    for (name, ni) in configs {
        let c = MacroConfig {
            nonideal: ni,
            ..cfg.clone()
        };
        println!("{:>34} {:>14.9}", name, linearity_r2(&c, 5, 2048));
    }

    // End-to-end MAC error when the mirror is removed (Fig 7b, functional).
    println!(
        "\nmean relative MAC error in droop mode: {:.1} %",
        fig7::droop_mac_error(&cfg, 72) * 100.0
    );

    h.finish();
}

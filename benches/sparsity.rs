//! Bench: the event-driven sparsity sweep (DESIGN.md S17, §Perf in
//! EXPERIMENTS.md) — density ∈ {0.01, 0.1, 0.5, 1.0} × batch ∈ {1, 64}
//! on the three forced fast-path engines (dense stream, active-row
//! event lists, quantized level planes). All three are exact on the
//! ideal macro (event-list bitwise = dense; quantized = the integer
//! oracle), so every row measures the same math — the table is purely
//! the wall-clock shape of event-driven execution.
//!
//! ```bash
//! cargo bench --bench sparsity            # full run
//! cargo bench --bench sparsity -- --test  # CI smoke (fast mode)
//! ```

use spikemram::benchlib::{black_box, Harness};
use spikemram::config::{MacroConfig, MvmEngine};
use spikemram::macro_model::{CimMacro, MvmBatch};
use spikemram::util::rng::Rng;

fn main() {
    if std::env::args().any(|a| a == "--test") {
        std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
    }
    let mut h = Harness::new("sparsity");
    let cfg = MacroConfig::default();
    let mut rng = Rng::new(17);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    let mut m = CimMacro::new(cfg.clone());
    m.program(&codes);

    let engines = [
        ("dense", MvmEngine::Dense),
        ("event_list", MvmEngine::EventList),
        ("quantized", MvmEngine::Quantized),
    ];
    let mut ledger = MvmBatch::default();
    for (dname, density) in
        [("d001", 0.01), ("d010", 0.1), ("d050", 0.5), ("d100", 1.0)]
    {
        // One fixed input set per density point, shared by all engines
        // and batch sizes so the rows compare like for like.
        let xs: Vec<u32> = (0..64 * cfg.rows)
            .map(|_| {
                if rng.f64() < density {
                    1 + rng.below(255) as u32
                } else {
                    0
                }
            })
            .collect();
        for batch in [1usize, 64] {
            let flat = &xs[..batch * cfg.rows];
            for (ename, engine) in engines {
                m.set_engine(engine);
                let r = h.bench_function_n(
                    &format!("mvm_{dname}_b{batch}_{ename}"),
                    batch as u64,
                    |b| {
                        b.iter(|| {
                            m.mvm_batch_strided_into(
                                black_box(flat),
                                cfg.rows,
                                &mut ledger,
                            );
                            ledger.total_active_rows()
                        })
                    },
                );
                h.note(&format!(
                    "{:.3} µs/op on {ename}",
                    r.per_op_median_ns() / 1e3
                ));
            }
            println!(
                "    [{dname} b{batch}] {}/{} rows active \
                 ({:.1} % occupancy)",
                ledger.total_active_rows(),
                ledger.row_slots(),
                100.0 * ledger.occupancy()
            );
        }
    }

    h.finish();
}

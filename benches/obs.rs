//! Bench: tracing overhead (DESIGN.md S20) — the overhead contract.
//!
//! The macro MVM hot path runs at B ∈ {1, 64} with tracing disabled and
//! with every kind enabled. Disabled tracing costs one relaxed atomic
//! load per record site and must stay within ~1% of the PR-6 hotpath
//! medians; enabled tracing buffers one ring event per span and must
//! stay within ~10% at stream densities (EXPERIMENTS.md §Perf records
//! the band; ci.sh smoke-runs this in fast mode → `BENCH_obs.json`).
//!
//! ```bash
//! cargo bench --bench obs            # full run
//! cargo bench --bench obs -- --test  # CI smoke (fast mode)
//! ```

use spikemram::benchlib::{black_box, Harness};
use spikemram::config::{MacroConfig, TraceConfig};
use spikemram::macro_model::{CimMacro, MvmBatch};
use spikemram::obs;
use spikemram::util::rng::Rng;

fn main() {
    if std::env::args().any(|a| a == "--test") {
        std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
    }
    let mut h = Harness::new("obs");
    let cfg = MacroConfig::default();
    let mut rng = Rng::new(7);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    let mut m = CimMacro::new(cfg.clone());
    m.program(&codes);
    // Stream-density inputs (~25% active rows): the regime the enabled
    // band is specified at.
    let xs: Vec<Vec<u32>> = (0..64)
        .map(|_| {
            (0..cfg.rows)
                .map(|_| {
                    if rng.f64() < 0.25 {
                        1 + rng.below(255) as u32
                    } else {
                        0
                    }
                })
                .collect()
        })
        .collect();
    let mut ledger = MvmBatch::default();

    let mut off_per_op = [0.0f64; 2];
    for (mode, tcfg) in
        [("off", TraceConfig::off()), ("on", TraceConfig::all())]
    {
        obs::install(&tcfg);
        for (bi, batch) in [1usize, 64].into_iter().enumerate() {
            let r = h.bench_function_n(
                &format!("mvm_batch{batch}_trace_{mode}"),
                batch as u64,
                |b| {
                    b.iter(|| {
                        m.mvm_batch_into(black_box(&xs[..batch]), &mut ledger);
                        ledger.y_mac(batch - 1)[0]
                    })
                },
            );
            if mode == "off" {
                off_per_op[bi] = r.per_op_median_ns();
            } else {
                h.note(&format!(
                    "B={batch}: enabled/disabled per-op ratio {:.3}",
                    r.per_op_median_ns() / off_per_op[bi]
                ));
            }
        }
        if mode == "on" {
            // Empty the rings so the enabled rows measure steady-state
            // recording, not drop-oldest churn of a saturated ring.
            let rep = obs::drain();
            h.note(&format!(
                "drained {} events ({} dropped) after enabled rows",
                rep.events.len(),
                rep.dropped
            ));
        }
    }
    obs::install(&TraceConfig::off());
    h.finish();
}

//! Bench: L3 hot paths (DESIGN.md §9, S16) — the structures the perf
//! pass optimizes: event queue throughput, flag tree, single macro MVM
//! at several sparsities, the batched MVM engine at B ∈ {1, 8, 64},
//! scheduler dispatch, and the serving loop. §Perf in EXPERIMENTS.md
//! records before/after from this bench; `BENCH_hotpath.json` carries
//! the machine-readable trajectory.
//!
//! ```bash
//! cargo bench --bench hotpath            # full run
//! cargo bench --bench hotpath -- --test  # CI smoke (fast mode)
//! ```

use spikemram::benchlib::{black_box, Harness};
use spikemram::config::{MacroConfig, MvmEngine};
use spikemram::coordinator::{Policy, Scheduler, TileOp, TiledMatrix};
use spikemram::event::{EventKind, EventQueue, FlagTree};
use spikemram::macro_model::{CimMacro, MvmBatch};
use spikemram::util::rng::Rng;

fn main() {
    if std::env::args().any(|a| a == "--test") {
        std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
    }
    let mut h = Harness::new("hotpath");
    let cfg = MacroConfig::default();

    // --- event queue -----------------------------------------------------
    h.bench_function("event_queue_push_pop_256", |b| {
        let mut q = EventQueue::with_capacity(256);
        let times: Vec<f64> = {
            let mut rng = Rng::new(1);
            (0..128).map(|_| rng.uniform(0.0, 51.0)).collect()
        };
        b.iter(|| {
            q.reset();
            for (i, &t) in times.iter().enumerate() {
                q.push(0.0, EventKind::RowRise { row: i as u32 });
                q.push(t, EventKind::RowFall { row: i as u32 });
            }
            let mut last = 0.0;
            while let Some(ev) = q.pop() {
                last = ev.t_ns;
            }
            last
        })
    });

    h.bench_function("flag_tree_full_cycle_128", |b| {
        let mut f = FlagTree::new(128);
        b.iter(|| {
            f.reset();
            for i in 0..128 {
                f.assert_row(i, i as f64 * 0.01);
            }
            for i in 0..128 {
                f.deassert_row(i, 10.0 + i as f64 * 0.01);
            }
            f.intervals().len()
        })
    });

    // --- macro MVM at varying sparsity ------------------------------------
    let mut rng = Rng::new(2);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    let mut m = CimMacro::new(cfg.clone());
    m.program(&codes);
    // Pin the historical trajectory rows to the PR-3 dense streaming
    // engine: since DESIGN.md S17, `Auto` resolves to the quantized
    // level-plane engine on an ideal macro, and these rows must keep
    // measuring the same code across PRs (benches/sparsity.rs carries
    // the engine-vs-engine comparison).
    m.set_engine(MvmEngine::Dense);
    for (name, density) in
        [("dense", 1.0), ("half", 0.5), ("sparse_1_16", 1.0 / 16.0)]
    {
        let x: Vec<u32> = (0..cfg.rows)
            .map(|_| {
                if rng.f64() < density {
                    1 + rng.below(255) as u32
                } else {
                    0
                }
            })
            .collect();
        let mut last = None;
        h.bench_function(&format!("macro_mvm_{name}"), |b| {
            b.iter(|| {
                let r = m.mvm(black_box(&x));
                let out = (r.latency_ns, r.events);
                last = Some(r);
                out
            })
        });
        if let Some(r) = last {
            h.note(&format!(
                "simulated: {} events, latency {:.1} ns, {:.1} pJ",
                r.events,
                r.latency_ns,
                r.energy.total_pj()
            ));
        }
    }

    // --- batched MVM engine (DESIGN.md S16) -------------------------------
    // Per-op medians for B ∈ {1, 8, 64} dense batches vs the serial fast
    // path: the batch engine streams each conductance row once per batch
    // and the reused ledger makes the steady state allocation-free.
    let xs64: Vec<Vec<u32>> = (0..64)
        .map(|_| (0..cfg.rows).map(|_| 1 + rng.below(255) as u32).collect())
        .collect();
    let serial = h.bench_function_n("macro_mvm_serial_dense_x8", 8, |b| {
        b.iter(|| {
            let mut acc = 0.0f64;
            for x in &xs64[..8] {
                acc += m.mvm(black_box(x)).t_out_ns[0];
            }
            acc
        })
    });
    let serial_per_op = serial.per_op_median_ns();
    let mut ledger = MvmBatch::default();
    for batch in [1usize, 8, 64] {
        let r = h.bench_function_n(
            &format!("macro_mvm_batch{batch}_dense"),
            batch as u64,
            |b| {
                b.iter(|| {
                    m.mvm_batch_into(black_box(&xs64[..batch]), &mut ledger);
                    ledger.y_mac(batch - 1)[0]
                })
            },
        );
        h.note(&format!(
            "{:.2}× the serial per-op median",
            r.per_op_median_ns() / serial_per_op
        ));
    }

    // The production default: Auto resolves to the quantized
    // level-plane engine on this ideal macro (DESIGN.md S17).
    m.set_engine(MvmEngine::Auto);
    let r = h.bench_function_n("macro_mvm_batch8_auto", 8, |b| {
        b.iter(|| {
            m.mvm_batch_into(black_box(&xs64[..8]), &mut ledger);
            ledger.y_mac(7)[0]
        })
    });
    h.note(&format!(
        "{:.2}× the serial dense per-op median ({:?} engine)",
        r.per_op_median_ns() / serial_per_op,
        ledger.engine_used()
    ));

    // --- scheduler dispatch ----------------------------------------------
    let big_codes: Vec<u8> = (0..256 * 128).map(|i| (i % 4) as u8).collect();
    let tm = TiledMatrix::new(&big_codes, 256, 128, 128);
    let ops: Vec<TileOp> = (0..16)
        .map(|i| TileOp {
            tile_idx: i % tm.num_tiles(),
            x: (0..128).map(|j| ((i * 37 + j) % 256) as u32).collect(),
            arrival_ns: 0.0,
        })
        .collect();
    for policy in [Policy::RoundRobin, Policy::TileAffinity] {
        h.bench_function(&format!("scheduler_16ops_{policy:?}"), |b| {
            b.iter(|| {
                let mut s = Scheduler::new(&cfg, 4, policy);
                s.run(black_box(&tm), black_box(&ops)).makespan_ns
            })
        });
    }

    h.finish();
}

//! Bench: Fig 6 regeneration (experiments E4/E5) — power breakdown and
//! sensing-energy comparison, plus scaling sweeps of every readout model
//! (precision and array size) beyond the paper's single anchor points.

use spikemram::baselines::{
    anchors, CogReadout, LifNeuron, LifReadout, OsgReadout, RateIfc, Readout,
    SarAdc, Tdc,
};
use spikemram::benchlib::Harness;
use spikemram::config::MacroConfig;
use spikemram::repro::fig6;

fn main() {
    let mut h = Harness::new("fig6_energy");
    let cfg = MacroConfig::default();

    h.bench_function("fig6a_monte_carlo_20_mvms", |b| {
        b.iter(|| fig6::run_fig6a(&cfg, 20, 61))
    });
    h.bench_function("fig6b_model_sweep", |b| {
        b.iter(|| fig6::run_fig6b(&cfg))
    });

    println!("\n{}", fig6::render_fig6a(&fig6::run_fig6a(&cfg, 50, 61)));
    println!("{}", fig6::render_fig6b(&fig6::run_fig6b(&cfg)));

    // Extended sweep: all readouts across precision (model-generated).
    println!("per-conversion energy (fJ) vs input precision:");
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>14} {:>14} {:>14}",
        "bits", "OSG(ours)", "SAR-ADC", "COG", "TDC", "LIF", "RateIFC"
    );
    let ours = OsgReadout::new(cfg.clone());
    let adc = SarAdc::calibrated(8, anchors::ADC_DAC24_FJ);
    let cog = CogReadout::calibrated(8, anchors::SPIKE_DAC20_FJ);
    let tdc = Tdc::calibrated(8, anchors::TDC_NATURE22_FJ);
    let lif = LifReadout::new(LifNeuron::default(), 2.0);
    let ifc = RateIfc::default();
    for bits in 4..=10u32 {
        println!(
            "{:>6} {:>14.1} {:>14.1} {:>14.1} {:>14.1} {:>14.1} {:>14.1}",
            bits,
            ours.energy_per_conversion_fj(bits),
            adc.energy_per_conversion_fj(bits),
            cog.energy_per_conversion_fj(bits),
            tdc.energy_per_conversion_fj(bits),
            lif.energy_per_conversion_fj(bits),
            ifc.energy_per_conversion_fj(bits),
        );
    }

    // LIF nonlinearity headline (the §II-B accuracy critique, quantified).
    let nl = LifNeuron::default().nonlinearity(2.0, 2000.0, 64);
    println!(
        "\nLIF rate-readout nonlinearity: {:.1} % of full scale \
         (OSG max deviation: <1e-6 %, see fig7a)",
        nl * 100.0
    );

    h.finish();
}

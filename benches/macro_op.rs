//! Bench: end-to-end macro operation across backends — the behavioral
//! simulator vs the PJRT-executed AOT artifact (when `artifacts/` exists)
//! — plus the SNN inference pipeline and the serving loop. This is the
//! bench behind the §Perf L3 numbers in EXPERIMENTS.md.

use std::time::Duration;

use spikemram::benchlib::{black_box, Harness};
use spikemram::config::{LevelMap, MacroConfig};
use spikemram::coordinator::{BackendKind, MacroServer, ServerConfig};
use spikemram::macro_model::{CimMacro, MvmBatch};
use spikemram::runtime::{Runtime, Value};
use spikemram::snn;
use spikemram::util::rng::Rng;

fn main() {
    if std::env::args().any(|a| a == "--test") {
        std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
    }
    let mut h = Harness::new("macro_op");
    let cfg = MacroConfig::default();
    let mut rng = Rng::new(3);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    let x: Vec<u32> = (0..cfg.rows).map(|_| rng.below(256) as u32).collect();

    // --- behavioral sim ---------------------------------------------------
    let mut m = CimMacro::new(cfg.clone());
    m.program(&codes);
    let r = h.bench_function("sim_mvm_single", |b| {
        b.iter(|| m.mvm(black_box(&x)).t_out_ns[0])
    });
    let per_op_ns = r.median_ns();
    h.note(&format!(
        "{:.1} MMAC/s simulated MAC throughput",
        (cfg.rows * cfg.cols) as f64 / per_op_ns * 1e3
    ));

    // --- batched sim (DESIGN.md S16): B ∈ {8, 64} ---------------------------
    let xs: Vec<Vec<u32>> = (0..64)
        .map(|_| (0..cfg.rows).map(|_| rng.below(256) as u32).collect())
        .collect();
    let mut ledger = MvmBatch::default();
    for batch in [8usize, 64] {
        let r = h.bench_function_n(
            &format!("sim_mvm_batch{batch}"),
            batch as u64,
            |b| {
                b.iter(|| {
                    m.mvm_batch_into(black_box(&xs[..batch]), &mut ledger);
                    ledger.y_mac(batch - 1)[0]
                })
            },
        );
        h.note(&format!(
            "{:.1} MMAC/s through the batched engine (batch {batch})",
            (cfg.rows * cfg.cols) as f64 / r.per_op_median_ns() * 1e3
        ));
    }

    // --- PJRT artifact (batch 8) -------------------------------------------
    let artifacts = std::env::var("SPIKEMRAM_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&artifacts).join("manifest.json").exists() {
        let mut rt = Runtime::new(&artifacts).expect("pjrt");
        let exe = rt.load("spiking_mvm_b8_128x128").expect("artifact");
        let t_in: Vec<f32> = (0..8 * cfg.rows)
            .map(|i| x[i % cfg.rows] as f32 * cfg.t_bit_ns as f32)
            .collect();
        let codes_i32: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
        let r = h.bench_function("pjrt_mvm_batch8", |b| {
            b.iter(|| {
                exe.run_f32(&[
                    Value::f32(t_in.clone(), &[8, cfg.rows]),
                    Value::i32(codes_i32.clone(), &[cfg.rows, cfg.cols]),
                ])
                .unwrap()[0][0]
            })
        });
        h.note(&format!(
            "{:.1} MMAC/s through the AOT artifact (batch 8)",
            8.0 * (cfg.rows * cfg.cols) as f64 / r.median_ns() * 1e3
        ));

        let exe32 = rt.load("spiking_mvm_b32_128x128").expect("artifact");
        let t_in32: Vec<f32> = (0..32 * cfg.rows)
            .map(|i| x[i % cfg.rows] as f32 * cfg.t_bit_ns as f32)
            .collect();
        let r = h.bench_function("pjrt_mvm_batch32", |b| {
            b.iter(|| {
                exe32
                    .run_f32(&[
                        Value::f32(t_in32.clone(), &[32, cfg.rows]),
                        Value::i32(codes_i32.clone(), &[cfg.rows, cfg.cols]),
                    ])
                    .unwrap()[0][0]
            })
        });
        h.note(&format!(
            "{:.1} MMAC/s through the AOT artifact (batch 32)",
            32.0 * (cfg.rows * cfg.cols) as f64 / r.median_ns() * 1e3
        ));
    } else {
        println!("(skipping PJRT benches: run `make artifacts` first)");
    }

    // --- serving loop -------------------------------------------------------
    let server = MacroServer::start(
        cfg.clone(),
        codes.clone(),
        ServerConfig {
            workers: 4,
            max_batch: 8,
            batch_timeout: Duration::from_micros(100),
            backend: BackendKind::Sim,
        },
    )
    .expect("server");
    h.bench_function("server_roundtrip_16_concurrent", |b| {
        b.iter(|| {
            let rxs: Vec<_> =
                (0..16).map(|_| server.submit(x.clone())).collect();
            rxs.into_iter().map(|rx| rx.recv().unwrap()[0]).sum::<f64>()
        })
    });
    server.shutdown();

    // --- SNN inference -------------------------------------------------------
    let data = snn::Dataset::generate(64, 5);
    let (model, _) = snn::train(&data, 3, 5);
    let mut mm =
        snn::MacroMlp::from_float(&model, &data, &cfg, LevelMap::DeviceTrue);
    let px = data.features_u8(0);
    h.bench_function("snn_single_inference_sim", |b| {
        b.iter(|| mm.predict(black_box(&px)).0)
    });
    let batch_px: Vec<Vec<u32>> =
        (0..8).map(|i| data.features_u8(i % data.len())).collect();
    h.bench_function_n("snn_batch8_inference_sim", 8, |b| {
        b.iter(|| mm.forward_batch(black_box(&batch_px)).len())
    });

    h.finish();
}

//! Bench: the temporal streaming runtime (DESIGN.md S18, §Perf in
//! EXPERIMENTS.md) — timestep sweep T ∈ {1, 4, 16} × frame density
//! {0.05, 0.5} on the binary-spike path. One iteration is a full
//! T-step inference (reset → stream → readout) through the 3-stage
//! digit MLP on a 2×2 fabric mesh; the JSON rows carry per-timestep
//! medians (`ops_per_iter = T`), so the wall-clock shape of event-
//! driven *time* is directly comparable across T and density.
//!
//! ```bash
//! cargo bench --bench stream            # full run
//! cargo bench --bench stream -- --test  # CI smoke (fast mode)
//! ```

use spikemram::benchlib::{black_box, Harness};
use spikemram::config::{
    FabricConfig, LevelMap, MacroConfig, StreamConfig,
};
use spikemram::snn::{Dataset, Mlp};
use spikemram::stream::{collect_frames, PoissonStream, SpikingMlp};

fn main() {
    if std::env::args().any(|a| a == "--test") {
        std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
    }
    let mut h = Harness::new("stream");
    // Untrained weights: the bench measures the runtime, not the model.
    let calib = Dataset::generate(32, 5);
    let model = Mlp::new(6);
    let mut mlp = SpikingMlp::from_float(
        &model,
        &calib,
        &MacroConfig::default(),
        FabricConfig::square(2),
        LevelMap::DeviceTrue,
        &StreamConfig::default(),
    )
    .expect("2x2 mesh holds the digit MLP");

    for t in [1usize, 4, 16] {
        for (dname, density) in [("d005", 0.05), ("d050", 0.5)] {
            // One fixed Poisson stream per point: every sample times
            // identical frames.
            let mut src = PoissonStream::uniform(
                256,
                t,
                density,
                17 + t as u64,
            );
            let frames = collect_frames(&mut src);
            let r = h.bench_function_n(
                &format!("stream_t{t}_{dname}"),
                t as u64,
                |b| {
                    b.iter(|| {
                        mlp.run(black_box(&frames)).stats.active_rows
                    })
                },
            );
            h.note(&format!(
                "{:.2} µs per timestep at density {density}",
                r.per_op_median_ns() / 1e3
            ));
        }
    }

    h.finish();
}

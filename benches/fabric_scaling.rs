//! Fabric scaling bench (DESIGN.md S15, EXPERIMENTS.md §EX2): wall-clock
//! per routed MVM as the mesh grows from 1 to 64 macros, next to the
//! model's own latency/NoC-share numbers, plus a serial-vs-pipelined
//! two-layer streaming comparison.
//!
//! ```bash
//! cargo bench --bench fabric_scaling            # full sweep
//! cargo bench --bench fabric_scaling -- --test  # CI smoke (tiny+fast)
//! ```

use spikemram::benchlib::{black_box, Harness};
use spikemram::config::{FabricConfig, MacroConfig};
use spikemram::coordinator::TiledMatrix;
use spikemram::fabric::{FabricChip, FabricPipeline, StageRelay};
use spikemram::util::rng::Rng;

fn chip(cfg: &MacroConfig, g: usize, seed: u64) -> (FabricChip, Vec<u32>) {
    let dim = cfg.rows * g;
    let mut rng = Rng::new(seed);
    let codes: Vec<u8> = (0..dim * dim).map(|_| rng.below(4) as u8).collect();
    let tiled = TiledMatrix::new(&codes, dim, dim, cfg.rows);
    let chip = FabricChip::new(cfg, FabricConfig::square(g), vec![tiled])
        .expect("one shard per tile");
    let x: Vec<u32> = (0..dim).map(|_| rng.below(256) as u32).collect();
    (chip, x)
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    if test_mode {
        std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
    }
    let grids: &[usize] = if test_mode { &[1, 2] } else { &[1, 2, 4, 8] };
    let cfg = MacroConfig::default();
    let mut h = Harness::new("fabric_scaling");

    for &g in grids {
        let (mut c, x) = chip(&cfg, g, 7 + g as u64);
        let r =
            h.bench_function(&format!("fabric_mvm_{g}x{g}_mesh"), |b| {
                b.iter(|| black_box(c.mvm(&x).0))
            });
        let (_, lr) = c.mvm(&x);
        let share = lr.energy.noc_fj / lr.energy.total_fj();
        h.note(&format!(
            "{} macros: model {:.1} ns/MVM, NoC {:.1} %, {} hops — \
             wall {:.2} µs",
            g * g,
            lr.latency_ns,
            share * 100.0,
            lr.hops,
            r.median_ns() / 1e3
        ));
        // Batched mesh forward (DESIGN.md S16): one weight pass per
        // shard for the whole minibatch.
        let xs: Vec<Vec<u32>> = (0..8).map(|_| x.clone()).collect();
        let rb = h.bench_function_n(
            &format!("fabric_mvm_batch8_{g}x{g}_mesh"),
            8,
            |b| b.iter(|| black_box(c.mvm_batch(&xs).len())),
        );
        h.note(&format!(
            "{:.2}× the serial per-op median on this mesh",
            rb.per_op_median_ns() / r.median_ns()
        ));
    }

    // Two-layer streaming: serial chip vs thread-per-layer pipeline.
    let items = if test_mode { 8 } else { 64 };
    let mk_layers = |seed: u64| -> FabricChip {
        let mut rng = Rng::new(seed);
        let layers: Vec<TiledMatrix> = (0..2)
            .map(|_| {
                let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
                    .map(|_| rng.below(4) as u8)
                    .collect();
                TiledMatrix::new(&codes, cfg.rows, cfg.cols, cfg.rows)
            })
            .collect();
        FabricChip::new(&cfg, FabricConfig::square(2), layers).unwrap()
    };
    let mut rng = Rng::new(99);
    let inputs: Vec<Vec<u32>> = (0..items)
        .map(|_| (0..cfg.rows).map(|_| rng.below(256) as u32).collect())
        .collect();
    let requant = |y: Vec<f64>| -> Vec<u32> {
        y.into_iter()
            .map(|v| ((v / 40.0).round().max(0.0) as u32).min(255))
            .collect()
    };

    h.bench_function("two_layer_serial_chip", |b| {
        b.iter(|| {
            let mut c = mk_layers(31);
            let mut out = Vec::new();
            for x in &inputs {
                let mut v = x.clone();
                for li in 0..2 {
                    let r = c.forward_layer(li, &v);
                    v = requant(r.partials[0][0].clone());
                }
                out.push(v);
            }
            black_box(out)
        })
    });
    h.bench_function("two_layer_pipelined_executor", |b| {
        b.iter(|| {
            let relays: Vec<StageRelay> = (0..2)
                .map(|_| {
                    Box::new(move |_x: &[u32], mac: Vec<f64>| requant(mac))
                        as StageRelay
                })
                .collect();
            black_box(
                FabricPipeline::new(mk_layers(31), relays)
                    .run(inputs.clone())
                    .0,
            )
        })
    });
    h.note(&format!(
        "{items} items through 2 layers; pipeline overlaps layer \
         compute across threads"
    ));
    h.bench_function("two_layer_pipelined_batch4", |b| {
        b.iter(|| {
            let relays: Vec<StageRelay> = (0..2)
                .map(|_| {
                    Box::new(move |_x: &[u32], mac: Vec<f64>| requant(mac))
                        as StageRelay
                })
                .collect();
            black_box(
                FabricPipeline::new(mk_layers(31), relays)
                    .run_batched(inputs.clone(), 4)
                    .0,
            )
        })
    });

    h.finish();
}

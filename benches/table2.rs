//! Bench: Table II regeneration (experiment E8) — measures the wall cost
//! of the Monte-Carlo efficiency estimate and prints the final table,
//! then benchmarks the headline MVM at several input precisions to show
//! how the measured TOPS/W moves (the event-driven scaling story).

use spikemram::benchlib::{black_box, Harness};
use spikemram::config::MacroConfig;
use spikemram::energy::tops_per_watt;
use spikemram::macro_model::CimMacro;
use spikemram::repro::table2;
use spikemram::util::rng::Rng;

fn main() {
    let mut h = Harness::new("table2");
    let cfg = MacroConfig::default();

    h.bench_function("table2_monte_carlo_50_mvms", |b| {
        b.iter(|| table2::run(&cfg, 50, 42))
    });

    // Efficiency vs input precision (measured through the simulator).
    for bits in [4u32, 6, 8] {
        let cfg_b = MacroConfig {
            input_bits: bits,
            ..cfg.clone()
        };
        let mut m = CimMacro::new(cfg_b.clone());
        let mut rng = Rng::new(7 + bits as u64);
        let codes: Vec<u8> = (0..cfg_b.rows * cfg_b.cols)
            .map(|_| rng.below(4) as u8)
            .collect();
        m.program(&codes);
        let max = (1u64 << bits) as u64;
        let xs: Vec<Vec<u32>> = (0..8)
            .map(|_| {
                (0..cfg_b.rows).map(|_| rng.below(max) as u32).collect()
            })
            .collect();
        let mut energy = 0.0;
        let mut ops = 0u64;
        h.bench_function(&format!("mvm_sim_{bits}bit_input"), |b| {
            let mut i = 0usize;
            b.iter(|| {
                let r = m.mvm(black_box(&xs[i % xs.len()]));
                i += 1;
                energy += r.energy.total_fj();
                ops += cfg_b.ops_per_mvm();
                r.latency_ns
            })
        });
        if ops > 0 {
            h.note(&format!(
                "simulated efficiency at {bits}-bit inputs: {:.1} TOPS/W",
                tops_per_watt(ops, energy)
            ));
        }
    }

    // Print the regenerated table itself.
    println!("\n{}", table2::render(&table2::run(&cfg, 50, 42)));

    h.finish();
}
